//! Merge ops for graph (non-sequential) models: elementwise [`Add`] and
//! last-axis [`Concat`] — the two junction layers residual and
//! multi-branch networks are built from.
//!
//! Like every other kernel in `layers/`, the merge kernels are written
//! once, generic over [`Scalar`]: binding `f64` gives the reference trace,
//! [`crate::quant::EmulatedFp`] the precision-k witness, and
//! [`crate::caa::Caa`] the rigorous analysis. That genericity *is* the
//! bound propagation for merges:
//!
//! * `Add` performs one `Scalar::add` per element per extra input, so CAA
//!   charges exactly one rounding per accumulation — the merged value's
//!   absolute bound is the (rounded-up) sum of the branch bounds plus the
//!   addition roundings, and the interval enclosures combine by interval
//!   addition. Summation is left-to-right over the declared inbound order,
//!   which pins the rounding profile the witness runs must reproduce.
//! * `Concat` moves data without arithmetic: bounds and enclosures pass
//!   through each branch untouched (a pure gather — zero rounding charge).
//!
//! [`Add`]: crate::layers::Layer::Add
//! [`Concat`]: crate::layers::Layer::Concat

use crate::tensor::Scalar;
use anyhow::{bail, Result};

/// Output shape of an elementwise add: at least two inputs, all sharing
/// one shape (which is also the output shape).
pub(crate) fn add_output_shape(inputs: &[&[usize]]) -> Result<Vec<usize>> {
    if inputs.len() < 2 {
        bail!("add is a merge layer: it needs at least 2 inputs, got {}", inputs.len());
    }
    for s in &inputs[1..] {
        if *s != inputs[0] {
            bail!("add inputs must share a shape: {:?} vs {:?}", inputs[0], s);
        }
    }
    Ok(inputs[0].to_vec())
}

/// Output shape of a last-axis concatenation: at least two inputs of equal
/// rank, agreeing on every axis but the last; the last axes sum.
pub(crate) fn concat_output_shape(inputs: &[&[usize]]) -> Result<Vec<usize>> {
    if inputs.len() < 2 {
        bail!("concat is a merge layer: it needs at least 2 inputs, got {}", inputs.len());
    }
    let first = inputs[0];
    if first.is_empty() {
        bail!("concat inputs must have rank >= 1");
    }
    let lead = &first[..first.len() - 1];
    let mut last = 0usize;
    for s in inputs {
        if s.len() != first.len() || &s[..s.len() - 1] != lead {
            bail!(
                "concat inputs must agree on every axis but the last: {:?} vs {:?}",
                first,
                s
            );
        }
        last += s[s.len() - 1];
    }
    let mut out = lead.to_vec();
    out.push(last);
    Ok(out)
}

/// `acc[i] = acc[i] + src[i]` in the target arithmetic — the slice-level
/// kernel behind [`StepKind::Add`](crate::plan::StepKind::Add). The
/// executor seeds `acc` with the first branch and folds every further
/// branch in with this, so an n-way add costs `n - 1` rounded additions
/// per element, accumulated left to right. Batch-transparent: a
/// sample-major batched buffer is just a longer slice of independent
/// elements, so the batched executor calls this unchanged over all
/// samples at once.
pub(crate) fn add_assign_into<S: Scalar>(ctx: &S::Ctx, acc: &mut [S], src: &[S]) {
    debug_assert_eq!(acc.len(), src.len(), "add branches must have equal length");
    for (a, x) in acc.iter_mut().zip(src) {
        *a = a.add(x, ctx);
    }
}

/// Append row `r` of a row-major `[rows, width]` source to `out` — the
/// gather kernel behind [`StepKind::Concat`](crate::plan::StepKind::Concat).
/// Pure data movement: no `Scalar` operation is involved, so merges by
/// concatenation propagate bounds without any rounding charge.
pub(crate) fn concat_row_into<S: Clone>(r: usize, width: usize, src: &[S], out: &mut Vec<S>) {
    out.extend_from_slice(&src[r * width..(r + 1) * width]);
}

/// Batched concat gather behind
/// [`StepKind::Concat`](crate::plan::StepKind::Concat): for each of the
/// `batch` sample-major samples, interleave the rows of every input
/// (input `i` contributing `widths[i]` values per row), appending
/// sample-major output. Pure data movement like [`concat_row_into`] —
/// zero rounding charge, and per-sample output identical to the
/// single-sample gather.
pub(crate) fn concat_batch_into<S: Clone>(
    batch: usize,
    rows: usize,
    widths: &[usize],
    srcs: &[&[S]],
    out: &mut Vec<S>,
) {
    debug_assert_eq!(widths.len(), srcs.len(), "one width per concat input");
    for s in 0..batch {
        for r in 0..rows {
            for (src, &w) in srcs.iter().zip(widths) {
                let in_len = rows * w;
                concat_row_into(r, w, &src[s * in_len..(s + 1) * in_len], out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caa::{Caa, Ctx};
    use crate::interval::Interval;

    #[test]
    fn add_shape_requires_agreement() {
        assert_eq!(add_output_shape(&[&[4], &[4]]).unwrap(), vec![4]);
        assert_eq!(add_output_shape(&[&[2, 3], &[2, 3], &[2, 3]]).unwrap(), vec![2, 3]);
        assert!(add_output_shape(&[&[4]]).is_err(), "one input is not a merge");
        assert!(add_output_shape(&[&[4], &[5]]).is_err());
    }

    #[test]
    fn concat_shape_sums_last_axis() {
        assert_eq!(concat_output_shape(&[&[3], &[5]]).unwrap(), vec![8]);
        assert_eq!(
            concat_output_shape(&[&[6, 6, 2], &[6, 6, 3]]).unwrap(),
            vec![6, 6, 5]
        );
        assert!(concat_output_shape(&[&[3]]).is_err());
        assert!(concat_output_shape(&[&[2, 2], &[3, 2]]).is_err(), "leading dims differ");
        assert!(concat_output_shape(&[&[2, 2], &[2]]).is_err(), "ranks differ");
    }

    #[test]
    fn add_assign_matches_plain_sum() {
        let mut acc = vec![1.0f64, 2.0, 3.0];
        add_assign_into(&(), &mut acc, &[0.5, -2.0, 10.0]);
        assert_eq!(acc, vec![1.5, 0.0, 13.0]);
    }

    #[test]
    fn concat_rows_interleave() {
        // Two [2, 2] channel blocks concatenated along the last axis:
        // rows interleave, not append.
        let a = vec![1.0f64, 2.0, 3.0, 4.0];
        let b = vec![10.0f64, 20.0, 30.0, 40.0];
        let mut out = Vec::new();
        for r in 0..2 {
            concat_row_into(r, 2, &a, &mut out);
            concat_row_into(r, 2, &b, &mut out);
        }
        assert_eq!(out, vec![1.0, 2.0, 10.0, 20.0, 3.0, 4.0, 30.0, 40.0]);
    }

    #[test]
    fn caa_add_bounds_cover_branch_sum() {
        // The merged bound encloses the concrete sum of perturbed branches.
        let ctx = Ctx::new();
        let mut acc =
            vec![Caa::input(&ctx, Interval::point(0.25), 0.25)];
        let src = vec![Caa::input(&ctx, Interval::point(0.5), 0.5)];
        add_assign_into(&ctx, &mut acc, &src);
        let y = &acc[0];
        assert!(y.rounded().contains(0.75), "rounded range must cover the sum");
        assert!(y.abs_bound().is_finite() && y.abs_bound() > 0.0);
    }
}
