//! **The multi-model serving fleet** — precision-tagged routing over many
//! micro-batch queues, flushed fairly onto one coordinator [`Pool`].
//!
//! The single-plan [`crate::serve::MicroBatcher`] batches one model in one
//! arithmetic. Production traffic (the ROADMAP's fleet direction) is many
//! models and **mixed precision**: some callers want the f64 reference,
//! others the emulated-k arithmetic their certified precision bound was
//! computed for. The [`Fleet`] scheduler owns one pending queue per
//! `(model, format)` pair — the [`ServeFormat`] tag on every submitted
//! sample routes it into the right per-format sub-batch, so one model
//! serves `f64` and `EmulatedFp{k}` traffic concurrently through its
//! separately-compiled plans ([`Plan::for_format`]: fused reference for
//! f64, unfused witness-convention for emulated — served emulated results
//! are bit-identical to [`crate::quant::emulated_forward`]).
//!
//! **Fairness.** A single flusher thread walks the queues in rotation
//! (round-robin over *ripe* queues — full, timer-expired, or shutdown
//! drain), dispatching at most one batch per queue per pass, so a hot
//! model can never starve a cold one: every ripe queue is visited within
//! one rotation, and the latency bound [`FleetPolicy::max_wait`] ripens a
//! trickle-traffic queue no matter how busy the rest of the fleet is.
//!
//! **Admission control.** Layered on the serve layer's blocking
//! backpressure: [`Fleet::submit`] *rejects* with a typed [`AdmitError`]
//! (per-queue cap, fleet-wide cap, unknown model, bad geometry) instead of
//! blocking, so front ends can shed load; [`Fleet::submit_blocking`]
//! keeps the classic block-until-room behavior for in-process callers.
//!
//! **Hot swap.** [`Fleet::deploy`] atomically replaces a model's compiled
//! [`PlanSet`] under traffic. Every pending sample pins the `Arc` of the
//! plan set it was admitted under, and a flush never crosses a version
//! boundary (the batch drain stops at the first sample pinning a
//! different set), so in-flight tickets drain on the **old** plan while
//! new submits route to the new one — no dropped or misrouted ticket.
//!
//! **Shutdown ordering.** [`Fleet::shutdown`] wakes submitters blocked on
//! backpressure across *all* queues, lets the flusher drain every queue,
//! then waits for all in-flight pool flushes to finish — when it returns,
//! every admitted ticket has been resolved.
//!
//! ```
//! use rigor::coordinator::Pool;
//! use rigor::fleet::{Fleet, FleetPolicy};
//! use rigor::model::zoo;
//! use rigor::plan::ServeFormat;
//! use std::sync::Arc;
//!
//! let fleet = Fleet::new(Arc::new(Pool::new(2, 16)), FleetPolicy::default());
//! fleet.deploy("mlp", &zoo::tiny_mlp(1))?;
//! let f = fleet.submit("mlp", ServeFormat::F64, vec![0.1; 8])?;
//! let e = fleet.submit("mlp", ServeFormat::Emulated { k: 12 }, vec![0.1; 8])?;
//! assert_eq!(f.wait()?.len(), 3);
//! assert_eq!(e.wait()?.len(), 3);
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::coordinator::{Pool, PoolMetrics};
use crate::model::Model;
use crate::plan::{Fusion, KernelPath, Parallelism, Plan, ServeFormat};
use crate::serve::{run_batch_job, DriveOutcome, PendingSample, ServeMetrics, Slot, Ticket};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching and admission knobs for a [`Fleet`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetPolicy {
    /// Largest batch one flush dispatches (per queue).
    pub max_batch: usize,
    /// Flush a queue when its **oldest** pending sample has waited this
    /// long — the per-queue latency bound that also guarantees fairness
    /// for trickle traffic.
    pub max_wait: Duration,
    /// Per-queue pending cap: [`Fleet::submit`] rejects with
    /// [`AdmitError::QueueFull`] at this depth. Must be `>= max_batch`.
    pub max_queue_pending: usize,
    /// Fleet-wide pending cap across all queues:
    /// [`AdmitError::FleetFull`] at this depth. Must be
    /// `>= max_queue_pending`.
    pub max_fleet_pending: usize,
    /// Deadline stamped on every admitted sample: tickets still queued
    /// past it when their batch reaches the flush boundary resolve as
    /// [`crate::serve::ServeError::DeadlineExceeded`] instead of
    /// occupying a batch slot. `None` (the default) disables deadlines.
    pub default_deadline: Option<Duration>,
    /// Consecutive faulted drives before a queue enters degraded mode
    /// (scalar kernels, serial drives) — the per-queue fallback to the
    /// known-good escape-hatch path.
    pub degrade_after: usize,
    /// Total faulted drives a queue may accumulate before it is
    /// quarantined ([`AdmitError::Quarantined`] on admission). A hot swap
    /// ([`Fleet::deploy`]) or a manual [`Fleet::reinstate`] clears it.
    pub fault_budget: usize,
}

impl Default for FleetPolicy {
    /// 32-sample batches, 2 ms latency bound, 1024 pending per queue,
    /// 4096 fleet-wide, no deadline; degrade after 3 consecutive faults,
    /// quarantine after 8 total.
    fn default() -> FleetPolicy {
        FleetPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_queue_pending: 1024,
            max_fleet_pending: 4096,
            default_deadline: None,
            degrade_after: 3,
            fault_budget: 8,
        }
    }
}

/// Why the fleet refused a sample — the typed rejection that replaces
/// unbounded blocking at the admission boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// No model deployed under this id.
    UnknownModel {
        /// The id the caller asked for.
        model: String,
    },
    /// The format tag failed validation (emulated `k` outside `2..=53`).
    BadFormat {
        /// The rejected format.
        format: ServeFormat,
    },
    /// The sample length does not match the model's input geometry.
    WrongLen {
        /// Target model id.
        model: String,
        /// Expected input length.
        expected: usize,
        /// Submitted sample length.
        got: usize,
    },
    /// The `(model, format)` queue is at
    /// [`FleetPolicy::max_queue_pending`].
    QueueFull {
        /// Target model id.
        model: String,
        /// Target format.
        format: ServeFormat,
        /// The queue's depth at rejection time.
        depth: usize,
    },
    /// The whole fleet is at [`FleetPolicy::max_fleet_pending`].
    FleetFull {
        /// Total pending samples at rejection time.
        depth: usize,
    },
    /// The sample contains a NaN/Inf value — rejected at admission so a
    /// poisoned input can never reach a drive (or a certified bound).
    NonFinite {
        /// Target model id.
        model: String,
        /// Index of the first non-finite input value.
        index: usize,
    },
    /// The `(model, format)` queue exhausted its
    /// [`FleetPolicy::fault_budget`] and is quarantined: no new samples
    /// until a hot swap ([`Fleet::deploy`]) or a manual
    /// [`Fleet::reinstate`].
    Quarantined {
        /// Target model id.
        model: String,
        /// Target format.
        format: ServeFormat,
    },
    /// [`Fleet::shutdown`] has begun; no new samples are admitted.
    ShuttingDown,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::UnknownModel { model } => write!(f, "no model deployed as '{model}'"),
            AdmitError::BadFormat { format } => write!(f, "invalid serve format {format}"),
            AdmitError::WrongLen { model, expected, got } => {
                write!(f, "model '{model}' expects {expected} input values, got {got}")
            }
            AdmitError::QueueFull { model, format, depth } => {
                write!(f, "queue ({model}, {format}) full at {depth} pending")
            }
            AdmitError::FleetFull { depth } => {
                write!(f, "fleet full at {depth} pending samples")
            }
            AdmitError::NonFinite { model, index } => {
                write!(f, "model '{model}': input value at index {index} is not finite")
            }
            AdmitError::Quarantined { model, format } => {
                write!(f, "queue ({model}, {format}) is quarantined (fault budget exceeded)")
            }
            AdmitError::ShuttingDown => write!(f, "fleet is shutting down"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Identifies one micro-batch queue: a deployed model times the
/// arithmetic its tickets asked for.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct QueueKey {
    /// Deployed model id.
    pub model: String,
    /// Precision tag the queue's tickets carry.
    pub format: ServeFormat,
}

/// One deployed model's compiled serving plans — the unit [`Fleet::deploy`]
/// swaps atomically. Emulated traffic at every `k` shares one unfused
/// plan (the precision lives in the execution context, not the plan), so
/// a set is exactly two compiled plans plus dispatch metadata.
pub struct PlanSet {
    /// Fused reference plan serving [`ServeFormat::F64`] tickets.
    pub f64_plan: Arc<Plan>,
    /// Unfused witness-convention plan serving every
    /// [`ServeFormat::Emulated`] queue.
    pub emu_plan: Arc<Plan>,
    /// Kernel family both plans were compiled for.
    pub kernels: KernelPath,
    /// Deployment version: 1 on first deploy, +1 per hot swap.
    pub version: u64,
}

impl PlanSet {
    /// The plan serving `format` tickets.
    pub fn plan_for(&self, format: ServeFormat) -> &Arc<Plan> {
        match format {
            ServeFormat::F64 => &self.f64_plan,
            ServeFormat::Emulated { .. } => &self.emu_plan,
        }
    }
}

/// One pending sample plus the plan set it was admitted under (pinned so
/// a hot swap drains it on the old plans).
struct FleetPending {
    req: PendingSample,
    plans: Arc<PlanSet>,
}

#[derive(Default)]
struct FleetQueue {
    pending: VecDeque<FleetPending>,
    metrics: ServeMetrics,
    /// Total faulted drives charged against
    /// [`FleetPolicy::fault_budget`]; cleared by hot swap / reinstate.
    faults: usize,
    /// Faulted drives since the last clean one — trips degraded mode at
    /// [`FleetPolicy::degrade_after`].
    consecutive_faults: usize,
    /// Degraded: this queue's flushes run scalar/serial.
    degraded: bool,
    /// Quarantined: admission rejects with [`AdmitError::Quarantined`].
    quarantined: bool,
}

struct FleetState {
    queues: BTreeMap<QueueKey, FleetQueue>,
    models: HashMap<String, Arc<PlanSet>>,
    total_pending: usize,
    /// Round-robin position of the flusher's ripe-queue scan.
    cursor: usize,
    swaps: usize,
    rejected: usize,
    shutdown: bool,
}

struct FleetShared {
    state: Mutex<FleetState>,
    wake: Condvar,
    /// Signalled whenever a flush makes room; what
    /// [`Fleet::submit_blocking`] waits on (shutdown wakes all of them).
    room: Condvar,
    pool: Arc<Pool>,
    policy: FleetPolicy,
    /// Intra-drive parallelism for each flushed batch; `workers <= 1`
    /// keeps the original behavior of one serial drive per flush.
    par: Parallelism,
    /// Flushes handed to the pool but not yet finished (see
    /// [`Fleet::shutdown`]).
    inflight: Mutex<usize>,
    idle: Condvar,
}

/// Why a batch left its queue.
enum Cause {
    Full,
    Timer,
    Drain,
}

/// Per-queue view in a [`FleetSnapshot`].
#[derive(Clone, Debug)]
pub struct QueueSnapshot {
    /// The queue's key.
    pub key: QueueKey,
    /// Samples pending right now.
    pub depth: usize,
    /// The queue's cumulative counters.
    pub metrics: ServeMetrics,
    /// Faulted drives charged against the fault budget.
    pub faults: usize,
    /// Whether the queue runs its flushes on the degraded
    /// (scalar/serial) path.
    pub degraded: bool,
    /// Whether admission is rejecting with [`AdmitError::Quarantined`].
    pub quarantined: bool,
}

/// Per-model view in a [`FleetSnapshot`].
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// Deployed model id.
    pub model: String,
    /// Current deployment version.
    pub version: u64,
}

/// Point-in-time aggregate of the whole fleet.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    /// Every queue the fleet has seen traffic for, in key order.
    pub queues: Vec<QueueSnapshot>,
    /// Every deployed model and its version.
    pub models: Vec<ModelSnapshot>,
    /// Samples pending across all queues right now.
    pub total_pending: usize,
    /// Hot swaps performed ([`Fleet::deploy`] over an existing id).
    pub swaps: usize,
    /// Samples refused by admission control.
    pub rejected: usize,
    /// Queues currently quarantined (fault budget exceeded, awaiting a
    /// hot swap or [`Fleet::reinstate`]).
    pub quarantined: usize,
    /// Coordinator-pool counters at snapshot time (job queue depth
    /// high-water, submitted/completed) — without this, serve-side
    /// backpressure building up in the shared pool was invisible from
    /// the fleet view.
    pub pool: PoolMetrics,
}

impl FleetSnapshot {
    /// Total samples admitted across all queues.
    pub fn submitted(&self) -> usize {
        self.queues.iter().map(|q| q.metrics.submitted).sum()
    }

    /// Total batches flushed across all queues.
    pub fn batches(&self) -> usize {
        self.queues.iter().map(|q| q.metrics.batches).sum()
    }
}

/// The fleet scheduler. Deploy models, submit precision-tagged samples,
/// and read the aggregate snapshot; one flusher thread multiplexes every
/// queue onto the shared coordinator pool. See the module docs for the
/// scheduling, admission and hot-swap semantics.
pub struct Fleet {
    shared: Arc<FleetShared>,
    /// Taken (and joined) by the first [`Fleet::shutdown`] caller; the
    /// mutex lets shutdown run through a shared `Arc<Fleet>`.
    flusher: Mutex<Option<JoinHandle<()>>>,
}

impl Fleet {
    /// An empty fleet flushing onto `pool` under `policy`, with each
    /// flushed batch driven at the `RIGOR_WORKERS` parallelism (default:
    /// the pool's worker count).
    pub fn new(pool: Arc<Pool>, policy: FleetPolicy) -> Fleet {
        let par = Parallelism::from_env(pool.worker_count());
        Fleet::with_parallelism(pool, policy, par)
    }

    /// [`Fleet::new`] with an explicit intra-drive [`Parallelism`]
    /// instead of the `RIGOR_WORKERS` environment default.
    pub fn with_parallelism(pool: Arc<Pool>, policy: FleetPolicy, par: Parallelism) -> Fleet {
        assert!(policy.max_batch >= 1, "max_batch must be >= 1");
        assert!(
            policy.max_queue_pending >= policy.max_batch,
            "max_queue_pending ({}) must be >= max_batch ({})",
            policy.max_queue_pending,
            policy.max_batch
        );
        assert!(
            policy.max_fleet_pending >= policy.max_queue_pending,
            "max_fleet_pending ({}) must be >= max_queue_pending ({})",
            policy.max_fleet_pending,
            policy.max_queue_pending
        );
        let shared = Arc::new(FleetShared {
            state: Mutex::new(FleetState {
                queues: BTreeMap::new(),
                models: HashMap::new(),
                total_pending: 0,
                cursor: 0,
                swaps: 0,
                rejected: 0,
                shutdown: false,
            }),
            wake: Condvar::new(),
            room: Condvar::new(),
            pool,
            policy,
            par,
            inflight: Mutex::new(0),
            idle: Condvar::new(),
        });
        let flusher = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("rigor-fleet-flusher".into())
                .spawn(move || flusher_loop(sh))
                .expect("spawn fleet flusher")
        };
        Fleet { shared, flusher: Mutex::new(Some(flusher)) }
    }

    /// Deploy (or hot-swap) `model` under `model_id`: compile its serving
    /// plans outside the fleet lock, then atomically publish them.
    /// Returns the new deployment version (1 for a first deploy). Under a
    /// swap, already-queued tickets drain on the old plans; subsequent
    /// submits route to the new ones.
    pub fn deploy(&self, model_id: &str, model: &Model) -> Result<u64> {
        let kernels = KernelPath::from_env();
        let f64_plan = Arc::new(Plan::build_with_kernels(model, Fusion::Full, kernels)?);
        let emu_plan = Arc::new(Plan::build_with_kernels(model, Fusion::None, kernels)?);
        Ok(self.deploy_plans(model_id, f64_plan, emu_plan, kernels))
    }

    /// [`Fleet::deploy`] with pre-compiled plans (the cache-integrated
    /// path [`crate::api::FleetHandle`] uses). The two plans must share
    /// input/output geometry — they are compilations of one model.
    pub fn deploy_plans(
        &self,
        model_id: &str,
        f64_plan: Arc<Plan>,
        emu_plan: Arc<Plan>,
        kernels: KernelPath,
    ) -> u64 {
        assert_eq!(
            f64_plan.input_len(),
            emu_plan.input_len(),
            "plan set geometry mismatch for '{model_id}'"
        );
        let mut st = self.shared.state.lock().unwrap();
        let version = st.models.get(model_id).map(|p| p.version + 1).unwrap_or(1);
        if version > 1 {
            st.swaps += 1;
        }
        st.models.insert(
            model_id.to_string(),
            Arc::new(PlanSet { f64_plan, emu_plan, kernels, version }),
        );
        // A deploy is the operator saying "this model is good now": clear
        // quarantine, degraded mode, and the fault ledger on every queue
        // of the swapped model.
        for (key, q) in st.queues.iter_mut() {
            if key.model == model_id {
                q.quarantined = false;
                q.degraded = false;
                q.faults = 0;
                q.consecutive_faults = 0;
            }
        }
        version
    }

    /// Manually lift a quarantine on the `(model_id, format)` queue,
    /// clearing its fault ledger and degraded mode. Returns `true` if the
    /// queue was quarantined (`false`: unknown queue or not quarantined —
    /// nothing to lift). The other recovery path is a hot swap
    /// ([`Fleet::deploy`]), which clears every queue of the model.
    pub fn reinstate(&self, model_id: &str, format: ServeFormat) -> bool {
        let mut st = self.shared.state.lock().unwrap();
        let key = QueueKey { model: model_id.to_string(), format };
        match st.queues.get_mut(&key) {
            Some(q) if q.quarantined => {
                q.quarantined = false;
                q.degraded = false;
                q.faults = 0;
                q.consecutive_faults = 0;
                true
            }
            _ => false,
        }
    }

    /// Admit one `format`-tagged sample for `model_id`, returning a
    /// [`Ticket`] for its pending output — or a typed [`AdmitError`]
    /// **without blocking** when a cap is hit (load shedding: the caller
    /// decides whether to retry, queue elsewhere, or fail fast).
    pub fn submit(
        &self,
        model_id: &str,
        format: ServeFormat,
        sample: Vec<f64>,
    ) -> std::result::Result<Ticket, AdmitError> {
        self.admit(model_id, format, sample, false)
    }

    /// [`Fleet::submit`] that **blocks** on [`AdmitError::QueueFull`] /
    /// [`AdmitError::FleetFull`] until a flush makes room (classic
    /// backpressure for in-process callers); every other rejection is
    /// still immediate. Errors with [`AdmitError::ShuttingDown`] if the
    /// fleet shuts down while blocked — shutdown wakes these waiters
    /// across all queues.
    pub fn submit_blocking(
        &self,
        model_id: &str,
        format: ServeFormat,
        sample: Vec<f64>,
    ) -> std::result::Result<Ticket, AdmitError> {
        self.admit(model_id, format, sample, true)
    }

    fn admit(
        &self,
        model_id: &str,
        format: ServeFormat,
        sample: Vec<f64>,
        block: bool,
    ) -> std::result::Result<Ticket, AdmitError> {
        if format.validate().is_err() {
            return Err(AdmitError::BadFormat { format });
        }
        if let Some(index) = sample.iter().position(|v| !v.is_finite()) {
            crate::obs::nonfinite_input();
            let mut st = self.shared.state.lock().unwrap();
            st.rejected += 1;
            return Err(AdmitError::NonFinite { model: model_id.to_string(), index });
        }
        let mut st = self.shared.state.lock().unwrap();
        let (slot, trace) = loop {
            if st.shutdown {
                st.rejected += 1;
                return Err(AdmitError::ShuttingDown);
            }
            let Some(plans) = st.models.get(model_id) else {
                st.rejected += 1;
                return Err(AdmitError::UnknownModel { model: model_id.to_string() });
            };
            let expected = plans.plan_for(format).input_len();
            if sample.len() != expected {
                st.rejected += 1;
                return Err(AdmitError::WrongLen {
                    model: model_id.to_string(),
                    expected,
                    got: sample.len(),
                });
            }
            let key = QueueKey { model: model_id.to_string(), format };
            if st.queues.get(&key).is_some_and(|q| q.quarantined) {
                st.rejected += 1;
                return Err(AdmitError::Quarantined { model: model_id.to_string(), format });
            }
            let depth = st.queues.get(&key).map_or(0, |q| q.pending.len());
            if st.total_pending >= self.shared.policy.max_fleet_pending {
                if block {
                    st = self.shared.room.wait(st).unwrap();
                    continue;
                }
                st.rejected += 1;
                return Err(AdmitError::FleetFull { depth: st.total_pending });
            }
            if depth >= self.shared.policy.max_queue_pending {
                if block {
                    st = self.shared.room.wait(st).unwrap();
                    continue;
                }
                st.rejected += 1;
                return Err(AdmitError::QueueFull {
                    model: model_id.to_string(),
                    format,
                    depth,
                });
            }
            // Admitted: pin the current plan set and enqueue.
            let plans = Arc::clone(plans);
            let slot = Slot::new();
            let trace = crate::obs::next_trace_id();
            let enqueued = Instant::now();
            let deadline = self.shared.policy.default_deadline.map(|d| enqueued + d);
            let q = st.queues.entry(key).or_default();
            q.pending.push_back(FleetPending {
                req: PendingSample { sample, slot: Arc::clone(&slot), enqueued, deadline, trace },
                plans,
            });
            q.metrics.submitted += 1;
            q.metrics.queue_high_water = q.metrics.queue_high_water.max(q.pending.len());
            st.total_pending += 1;
            break (slot, trace);
        };
        drop(st);
        self.shared.wake.notify_all();
        Ok(Ticket { slot, trace })
    }

    /// Snapshot every queue's counters and every model's version.
    pub fn snapshot(&self) -> FleetSnapshot {
        let st = self.shared.state.lock().unwrap();
        let mut queues: Vec<QueueSnapshot> = st
            .queues
            .iter()
            .map(|(key, q)| QueueSnapshot {
                key: key.clone(),
                depth: q.pending.len(),
                metrics: q.metrics,
                faults: q.faults,
                degraded: q.degraded,
                quarantined: q.quarantined,
            })
            .collect();
        queues.sort_by(|a, b| a.key.cmp(&b.key));
        let mut models: Vec<ModelSnapshot> = st
            .models
            .iter()
            .map(|(m, p)| ModelSnapshot { model: m.clone(), version: p.version })
            .collect();
        models.sort_by(|a, b| a.model.cmp(&b.model));
        let quarantined = queues.iter().filter(|q| q.quarantined).count();
        FleetSnapshot {
            queues,
            models,
            total_pending: st.total_pending,
            swaps: st.swaps,
            rejected: st.rejected,
            quarantined,
            pool: self.shared.pool.metrics(),
        }
    }

    /// The current deployment version of `model_id`, if deployed.
    pub fn version(&self, model_id: &str) -> Option<u64> {
        self.shared.state.lock().unwrap().models.get(model_id).map(|p| p.version)
    }

    /// Shut the fleet down in order: refuse new admissions, wake every
    /// submitter blocked on backpressure across **all** queues (they
    /// error with [`AdmitError::ShuttingDown`]), let the flusher drain
    /// every queue, then wait for all in-flight pool flushes to finish —
    /// when this returns, every admitted ticket has been resolved.
    /// Takes `&self` so a shared fleet (`Arc<Fleet>`) can be shut down
    /// while submitters still hold clones. Idempotent (concurrent callers
    /// serialize on the flusher handle; late callers return once the
    /// in-flight count reaches zero); also run by `Drop`.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.wake.notify_all();
        self.shared.room.notify_all();
        // Holding the handle lock across the join serializes concurrent
        // shutdowns: the second caller blocks here until the flusher has
        // drained every queue, then finds the handle gone.
        {
            let mut handle = self.flusher.lock().unwrap();
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
        let mut n = self.shared.inflight.lock().unwrap();
        while *n > 0 {
            n = self.shared.idle.wait(n).unwrap();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Scan the queues round-robin from the rotation cursor and pick the
/// first ripe one (full / timer-expired / shutdown drain). Advancing the
/// cursor past the pick is what makes the scan fair: a queue that just
/// flushed goes to the back of the rotation, so every other ripe queue is
/// served before it flushes again.
fn pick_ripe(st: &mut FleetState, now: Instant, policy: &FleetPolicy) -> Option<(QueueKey, Cause)> {
    let keys: Vec<QueueKey> = st.queues.keys().cloned().collect();
    let n = keys.len();
    for i in 0..n {
        let idx = (st.cursor + i) % n;
        let q = &st.queues[&keys[idx]];
        let cause = if q.pending.len() >= policy.max_batch {
            Some(Cause::Full)
        } else if st.shutdown && !q.pending.is_empty() {
            Some(Cause::Drain)
        } else if q
            .pending
            .front()
            .is_some_and(|p| p.req.enqueued + policy.max_wait <= now)
        {
            Some(Cause::Timer)
        } else {
            None
        };
        if let Some(c) = cause {
            st.cursor = (idx + 1) % n;
            return Some((keys[idx].clone(), c));
        }
    }
    None
}

/// Drain one batch off a queue's front: up to `max_batch` samples, never
/// crossing a plan-set (hot-swap) boundary. Returns the samples and the
/// plan set they all pinned.
fn drain_one_version(
    q: &mut FleetQueue,
    max_batch: usize,
) -> (Vec<PendingSample>, Arc<PlanSet>) {
    let plans = Arc::clone(&q.pending.front().expect("ripe queue is nonempty").plans);
    let mut batch = Vec::new();
    while batch.len() < max_batch {
        match q.pending.front() {
            Some(p) if Arc::ptr_eq(&p.plans, &plans) => {
                batch.push(q.pending.pop_front().expect("front checked").req);
            }
            _ => break,
        }
    }
    (batch, plans)
}

/// The fleet flusher: wait until some queue is ripe, pick one fairly,
/// drain one batch, and hand it to the pool as a single job in the
/// queue's format. Runs until shutdown *and* every queue is empty, so
/// admitted tickets always resolve.
fn flusher_loop(sh: Arc<FleetShared>) {
    loop {
        let picked = {
            let mut st = sh.state.lock().unwrap();
            loop {
                let now = Instant::now();
                if let Some((key, cause)) = pick_ripe(&mut st, now, &sh.policy) {
                    let q = st.queues.get_mut(&key).expect("picked key exists");
                    let (batch, plans) = drain_one_version(q, sh.policy.max_batch);
                    // The degraded decision is captured under the state
                    // lock at drain time, so a concurrent reinstate or
                    // swap never half-applies to a dispatched batch.
                    let degraded = q.degraded;
                    q.metrics.batches += 1;
                    q.metrics.max_batch_observed = q.metrics.max_batch_observed.max(batch.len());
                    match cause {
                        Cause::Full => q.metrics.flushed_full += 1,
                        Cause::Timer => q.metrics.flushed_timer += 1,
                        Cause::Drain => q.metrics.flushed_drain += 1,
                    }
                    st.total_pending -= batch.len();
                    break Some((key, batch, plans, degraded));
                }
                if st.shutdown && st.total_pending == 0 {
                    break None;
                }
                // Nothing ripe: sleep until the earliest queue deadline
                // (or until a submit wakes us).
                let next = st
                    .queues
                    .values()
                    .filter_map(|q| q.pending.front().map(|p| p.req.enqueued + sh.policy.max_wait))
                    .min();
                match next {
                    Some(deadline) if deadline > now => {
                        st = sh.wake.wait_timeout(st, deadline - now).unwrap().0;
                    }
                    Some(_) => {} // ripened while scanning; re-pick
                    None => st = sh.wake.wait(st).unwrap(),
                }
            }
        };
        let Some((key, batch, plans, degraded)) = picked else {
            return;
        };
        // Room below the caps: wake blocked submitters. Like the serve
        // flusher, a full pool queue blocks *this* thread on submit,
        // keeping the backpressure chain intact end to end.
        sh.room.notify_all();
        *sh.inflight.lock().unwrap() += 1;
        let job_sh = Arc::clone(&sh);
        // `submit_or_run` keeps the ticket-resolution guarantee even if
        // the pool was shut down externally: the flush runs inline on
        // this thread instead of being dropped.
        sh.pool.submit_or_run(move || {
            let plan = plans.plan_for(key.format);
            // Degraded queues fall back to the scalar/serial escape
            // hatch — bit-identical outputs, none of the blocked/parallel
            // machinery that kept faulting.
            let (kernels, par) = if degraded {
                (KernelPath::Scalar, Parallelism::serial())
            } else {
                (plans.kernels, job_sh.par)
            };
            let outcome = run_batch_job(plan, kernels, key.format, batch, &job_sh.pool, par);
            account_outcome(&job_sh, &key, &outcome);
            let mut n = job_sh.inflight.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                job_sh.idle.notify_all();
            }
        });
    }
}

/// Charge a finished drive's outcome to its queue's fault ledger: a
/// faulted drive extends the consecutive streak (degraded mode at
/// [`FleetPolicy::degrade_after`]) and the total ledger (quarantine at
/// [`FleetPolicy::fault_budget`]); a clean drive resets the streak. Runs
/// after the drive, off the flusher thread, so accounting never blocks
/// other queues from flushing.
fn account_outcome(sh: &FleetShared, key: &QueueKey, outcome: &DriveOutcome) {
    let mut st = sh.state.lock().unwrap();
    let Some(q) = st.queues.get_mut(key) else {
        return;
    };
    q.metrics.deadline_missed += outcome.expired;
    if outcome.fault.is_some() {
        q.metrics.drive_faults += 1;
        q.faults += 1;
        q.consecutive_faults += 1;
        if !q.degraded && q.consecutive_faults >= sh.policy.degrade_after {
            q.degraded = true;
            crate::obs::degraded_entered();
        }
        if !q.quarantined && q.faults >= sh.policy.fault_budget {
            q.quarantined = true;
            crate::obs::quarantine_tripped();
        }
    } else if outcome.drove {
        q.consecutive_faults = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::plan::Arena;

    fn sample(n: usize, i: usize) -> Vec<f64> {
        (0..n).map(|j| ((i * n + j) % 13) as f64 / 13.0).collect()
    }

    fn small_policy() -> FleetPolicy {
        FleetPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue_pending: 64,
            max_fleet_pending: 128,
            ..FleetPolicy::default()
        }
    }

    #[test]
    fn routes_two_models_two_formats_bitwise() {
        let mlp = zoo::tiny_mlp(21);
        let cnn = zoo::tiny_cnn(22);
        let cnn_n: usize = cnn.input_shape.iter().product();
        let fleet = Fleet::new(Arc::new(Pool::new(2, 16)), small_policy());
        fleet.deploy("mlp", &mlp).unwrap();
        fleet.deploy("cnn", &cnn).unwrap();
        let k = 12u32;
        let emu = ServeFormat::Emulated { k };

        let mut tickets = Vec::new();
        for i in 0..6 {
            tickets.push(("mlp", ServeFormat::F64, 8, i, fleet.submit("mlp", ServeFormat::F64, sample(8, i)).unwrap()));
            tickets.push(("mlp", emu, 8, i, fleet.submit("mlp", emu, sample(8, i)).unwrap()));
            tickets.push(("cnn", ServeFormat::F64, cnn_n, i, fleet.submit("cnn", ServeFormat::F64, sample(cnn_n, i)).unwrap()));
            tickets.push(("cnn", emu, cnn_n, i, fleet.submit("cnn", emu, sample(cnn_n, i)).unwrap()));
        }
        let ref_mlp = Plan::for_reference(&mlp).unwrap();
        let ref_cnn = Plan::for_reference(&cnn).unwrap();
        let emu_mlp = Plan::unfused(&mlp).unwrap();
        let emu_cnn = Plan::unfused(&cnn).unwrap();
        let mut arena: Arena<f64> = Arena::new();
        for (model, format, n, i, t) in tickets {
            let got = t.wait().unwrap();
            let want: Vec<f64> = match (model, format) {
                ("mlp", ServeFormat::F64) => {
                    ref_mlp.execute::<f64>(&(), &sample(n, i), &mut arena).unwrap().to_vec()
                }
                ("cnn", ServeFormat::F64) => {
                    ref_cnn.execute::<f64>(&(), &sample(n, i), &mut arena).unwrap().to_vec()
                }
                ("mlp", _) => crate::quant::emulated_forward(&emu_mlp, k, &sample(n, i)).unwrap(),
                _ => crate::quant::emulated_forward(&emu_cnn, k, &sample(n, i)).unwrap(),
            };
            assert_eq!(got.len(), want.len(), "{model}/{format} request {i}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "{model}/{format} request {i}");
            }
        }
        let snap = fleet.snapshot();
        assert_eq!(snap.queues.len(), 4, "one queue per (model, format) pair");
        assert_eq!(snap.submitted(), 24);
        for q in &snap.queues {
            assert_eq!(q.metrics.submitted, 6, "balanced routing: {:?}", q.key);
        }
    }

    #[test]
    fn admission_rejects_typed() {
        let fleet = Fleet::new(
            Arc::new(Pool::new(1, 4)),
            FleetPolicy {
                max_batch: 2,
                max_wait: Duration::from_secs(30),
                max_queue_pending: 2,
                max_fleet_pending: 3,
                ..FleetPolicy::default()
            },
        );
        // Unknown model / bad format / wrong length are immediate.
        assert!(matches!(
            fleet.submit("nope", ServeFormat::F64, vec![0.0; 8]),
            Err(AdmitError::UnknownModel { .. })
        ));
        fleet.deploy("mlp", &zoo::tiny_mlp(3)).unwrap();
        assert!(matches!(
            fleet.submit("mlp", ServeFormat::Emulated { k: 99 }, vec![0.0; 8]),
            Err(AdmitError::BadFormat { .. })
        ));
        assert!(matches!(
            fleet.submit("mlp", ServeFormat::F64, vec![0.0; 5]),
            Err(AdmitError::WrongLen { expected: 8, got: 5, .. })
        ));
        // Stall the pool so flushes back up, then fill the caps. The
        // flusher may drain the queue into the (stalled) pool job, so
        // stuff the fleet faster than it can flush by using a queue cap
        // below max_batch's reach: max_batch 2, queue cap 2, fleet cap 3.
        fleet.shared.pool.submit(|| std::thread::sleep(Duration::from_millis(80))).unwrap();
        fleet.shared.pool.submit(|| std::thread::sleep(Duration::from_millis(80))).unwrap();
        // Hold the flusher's drain target busy: submit into two queues.
        let emu = ServeFormat::Emulated { k: 8 };
        let mut kept = Vec::new();
        let mut saw_queue_full = false;
        let mut saw_fleet_full = false;
        for i in 0..64 {
            match fleet.submit("mlp", ServeFormat::F64, sample(8, i)) {
                Ok(t) => kept.push(t),
                Err(AdmitError::QueueFull { .. }) => {
                    saw_queue_full = true;
                    break;
                }
                Err(AdmitError::FleetFull { .. }) => {
                    saw_fleet_full = true;
                    break;
                }
                Err(e) => panic!("unexpected rejection: {e}"),
            }
        }
        assert!(saw_queue_full || saw_fleet_full, "caps never engaged");
        let _ = fleet.submit("mlp", emu, sample(8, 0));
        for t in kept {
            assert_eq!(t.wait().unwrap().len(), 3);
        }
        assert!(fleet.snapshot().rejected >= 3);
    }

    #[test]
    fn fair_flushing_under_hot_and_cold_load() {
        // A hot queue (many submitters) must not starve a cold one: the
        // cold queue's tickets resolve via the timer path while the hot
        // queue stays saturated.
        let fleet = Arc::new(Fleet::new(
            Arc::new(Pool::new(2, 8)),
            FleetPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                max_queue_pending: 32,
                max_fleet_pending: 128,
                ..FleetPolicy::default()
            },
        ));
        fleet.deploy("hot", &zoo::tiny_mlp(31)).unwrap();
        fleet.deploy("cold", &zoo::tiny_mlp(32)).unwrap();
        let hot = {
            let f = Arc::clone(&fleet);
            std::thread::spawn(move || {
                let mut tickets = Vec::new();
                for i in 0..200 {
                    tickets.push(f.submit_blocking("hot", ServeFormat::F64, sample(8, i)).unwrap());
                }
                tickets
            })
        };
        let mut cold_tickets = Vec::new();
        for i in 0..10 {
            cold_tickets.push(fleet.submit_blocking("cold", ServeFormat::F64, sample(8, i)).unwrap());
            std::thread::sleep(Duration::from_millis(1));
        }
        for t in cold_tickets {
            assert_eq!(t.wait().unwrap().len(), 3, "cold queue starved");
        }
        for t in hot.join().unwrap() {
            assert_eq!(t.wait().unwrap().len(), 3);
        }
        let snap = fleet.snapshot();
        let cold_q = snap
            .queues
            .iter()
            .find(|q| q.key.model == "cold")
            .expect("cold queue exists");
        assert!(cold_q.metrics.batches >= 1);
        assert_eq!(snap.submitted(), 210);
    }

    #[test]
    fn hot_swap_drains_inflight_on_old_plan() {
        // Queue tickets against v1, swap to v2 (different weights), then
        // queue more: the first batch must carry v1's bits, the second
        // v2's — no ticket dropped, none misrouted across the swap.
        let m1 = zoo::tiny_mlp(41);
        let m2 = zoo::tiny_mlp(42);
        let fleet = Fleet::new(
            Arc::new(Pool::new(1, 4)),
            FleetPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
                max_queue_pending: 64,
                max_fleet_pending: 128,
                ..FleetPolicy::default()
            },
        );
        assert_eq!(fleet.deploy("m", &m1).unwrap(), 1);
        // Stall the pool so the pre-swap flush cannot race ahead.
        fleet.shared.pool.submit(|| std::thread::sleep(Duration::from_millis(40))).unwrap();
        let old: Vec<_> =
            (0..3).map(|i| fleet.submit("m", ServeFormat::F64, sample(8, i)).unwrap()).collect();
        assert_eq!(fleet.deploy("m", &m2).unwrap(), 2);
        assert_eq!(fleet.version("m"), Some(2));
        let new: Vec<_> =
            (0..3).map(|i| fleet.submit("m", ServeFormat::F64, sample(8, i)).unwrap()).collect();
        let p1 = Plan::for_reference(&m1).unwrap();
        let p2 = Plan::for_reference(&m2).unwrap();
        let mut arena: Arena<f64> = Arena::new();
        for (i, t) in old.into_iter().enumerate() {
            let got = t.wait().unwrap();
            let want = p1.execute::<f64>(&(), &sample(8, i), &mut arena).unwrap();
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.to_bits(), w.to_bits(), "pre-swap ticket {i} must see v1");
            }
        }
        for (i, t) in new.into_iter().enumerate() {
            let got = t.wait().unwrap();
            let want = p2.execute::<f64>(&(), &sample(8, i), &mut arena).unwrap();
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.to_bits(), w.to_bits(), "post-swap ticket {i} must see v2");
            }
        }
        assert_eq!(fleet.snapshot().swaps, 1);
    }

    #[test]
    fn shutdown_wakes_blocked_submitters_and_resolves_tickets() {
        let fleet = Arc::new(Fleet::new(
            Arc::new(Pool::new(1, 2)),
            FleetPolicy {
                max_batch: 2,
                max_wait: Duration::from_secs(30),
                max_queue_pending: 2,
                max_fleet_pending: 2,
                ..FleetPolicy::default()
            },
        ));
        fleet.deploy("m", &zoo::tiny_mlp(51)).unwrap();
        // Stall the pool and fill the fleet cap; the next blocking submit
        // parks on the room condvar.
        fleet.shared.pool.submit(|| std::thread::sleep(Duration::from_millis(60))).unwrap();
        let t0 = fleet.submit_blocking("m", ServeFormat::F64, sample(8, 0)).unwrap();
        let t1 = fleet.submit_blocking("m", ServeFormat::F64, sample(8, 1)).unwrap();
        let blocked = {
            let f = Arc::clone(&fleet);
            std::thread::spawn(move || f.submit_blocking("m", ServeFormat::F64, sample(8, 2)))
        };
        std::thread::sleep(Duration::from_millis(15)); // let it park
        fleet.shutdown();
        let r = blocked.join().unwrap();
        // Either the drain made room first (ticket resolves) or shutdown
        // rejected it — never a hang.
        if let Ok(t2) = r {
            assert_eq!(t2.wait().unwrap().len(), 3);
        }
        // Shutdown returned only after the in-flight flushes finished:
        // both accepted tickets are already resolved.
        assert!(t0.try_take().is_some(), "t0 unresolved after shutdown");
        assert!(t1.try_take().is_some(), "t1 unresolved after shutdown");
    }

    #[test]
    fn non_finite_inputs_rejected_on_both_admission_paths() {
        let fleet = Fleet::new(Arc::new(Pool::new(1, 4)), small_policy());
        fleet.deploy("m", &zoo::tiny_mlp(61)).unwrap();
        let mut bad = sample(8, 0);
        bad[2] = f64::NAN;
        assert!(matches!(
            fleet.submit("m", ServeFormat::F64, bad.clone()),
            Err(AdmitError::NonFinite { index: 2, .. })
        ));
        bad[2] = f64::NEG_INFINITY;
        assert!(matches!(
            fleet.submit_blocking("m", ServeFormat::F64, bad),
            Err(AdmitError::NonFinite { index: 2, .. })
        ));
        assert_eq!(fleet.snapshot().rejected, 2);
        // A clean sample still serves.
        let t = fleet.submit("m", ServeFormat::F64, sample(8, 0)).unwrap();
        assert_eq!(t.wait().unwrap().len(), 3);
    }

    #[test]
    fn reinstate_is_a_no_op_without_a_quarantine() {
        let fleet = Fleet::new(Arc::new(Pool::new(1, 4)), small_policy());
        fleet.deploy("m", &zoo::tiny_mlp(62)).unwrap();
        assert!(!fleet.reinstate("m", ServeFormat::F64), "nothing to lift");
        assert!(!fleet.reinstate("ghost", ServeFormat::F64));
        let snap = fleet.snapshot();
        assert_eq!(snap.quarantined, 0);
        assert!(snap.queues.iter().all(|q| !q.quarantined && !q.degraded));
    }
}
