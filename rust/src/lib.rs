//! # rigor — Rigorous Precision & Accuracy Analysis for Deep Learning
//!
//! Reproduction of *"A Framework for Semi-Automatic Precision and Accuracy
//! Analysis for Fast and Rigorous Deep Learning"* (Lauter & Volkova, 2020).
//!
//! The library re-evaluates a trained deep neural network with every scalar
//! replaced by a [`caa::Caa`] object — a *Combined Affine Arithmetic* value
//! carrying both an **absolute** and a **relative** rounding-error bound,
//! expressed in units of `u = 2^(1-k)` where `k` is the floating-point
//! precision. [`interval::Interval`] arithmetic supplies the range
//! information needed to combine and convert the bounds rigorously. From the
//! analysis output, [`analysis`] derives the minimum precision `k` that
//! provably prevents rounding-induced misclassification given a top-1
//! confidence margin `p* > 0.5`.
//!
//! The one public front door is [`api::Session`]: it owns the worker pool
//! and an LRU model cache, serves [`api::AnalysisRequest`]s serially or
//! fanned out, and returns [`api::AnalysisOutcome`]s with a versioned JSON
//! serialization. Internally every analysis executes through a compiled
//! [`plan::Plan`] — shape-resolved, optionally fused, arena-backed, and
//! topology-general: sequential chains and graph models (residual skips,
//! multi-branch merges, see [`model::Graph`]) lower to the same
//! buffer-pool step IR — cached next to the model; the per-layer
//! interpreter survives only as a deprecated equivalence oracle for
//! sequential models. The plan carries a batch axis
//! ([`plan::Plan::execute_batch`]): bulk traffic is served by the
//! [`serve`] micro-batcher — or, for many models and mixed-precision
//! traffic, by the [`fleet`] scheduler's precision-tagged queues — and
//! bulk per-sample analysis by [`api::Session::run_batch`].
//!
//! Layer map (three-layer rust+JAX+Pallas architecture):
//! * L3 (this crate): [`api`] service layer over the CAA+IA analysis
//!   engine, DNN inference engine, model loader, precision tailoring,
//!   analysis [`coordinator`], PJRT [`runtime`] (behind the `pjrt`
//!   feature).
//! * L2 (`python/compile/model.py`): the evaluation networks in JAX,
//!   AOT-lowered to HLO text artifacts.
//! * L1 (`python/compile/kernels/`): Pallas kernels (dense, conv2d, softmax,
//!   round-to-precision emulation).
//!
//! See `DESIGN.md` for the complete system inventory and experiment index.

#![warn(missing_docs)]

pub mod analysis;
pub mod api;
pub mod bench;
pub mod caa;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod faultinject;
pub mod fleet;
pub mod interval;
pub mod json;
pub mod layers;
pub mod model;
pub mod obs;
pub mod plan;
pub mod prop;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Crate-wide result type (anyhow-based).
pub type Result<T> = anyhow::Result<T>;
