//! Empirical checker for the paper's softmax theory (§IV, eqs. (10)–(11)).
//!
//! The paper proves that a softmax layer converts an *absolute* error
//! `|δ_i| <= δ̄` on its inputs into a *relative* error on its outputs
//! bounded by `|ε_i| <= 11/2 · max|δ_k|` (eq. (11)) — independent of the
//! vector length. The benchmark `softmax_bound` uses this module to
//! measure the observed amplification across random inputs and
//! perturbations, verifying the bound and its dimension-independence.

use crate::util::Rng;

/// Exact softmax in f64.
pub fn softmax_exact(x: &[f64]) -> Vec<f64> {
    let m = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vec<f64> = x.iter().map(|v| (v - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.iter().map(|v| v / s).collect()
}

/// One trial: perturb `x` by `δ` with `|δ_i| <= delta_bar`, return the
/// worst observed relative output deviation divided by `max|δ_k|` — the
/// *observed* amplification factor, to be compared against 11/2.
pub fn amplification_trial(rng: &mut Rng, x: &[f64], delta_bar: f64) -> f64 {
    let y = softmax_exact(x);
    let deltas: Vec<f64> = x.iter().map(|_| rng.range(-delta_bar, delta_bar)).collect();
    let max_delta = deltas.iter().map(|d| d.abs()).fold(0.0f64, f64::max);
    if max_delta == 0.0 {
        return 0.0;
    }
    let xp: Vec<f64> = x.iter().zip(&deltas).map(|(v, d)| v + d).collect();
    let yp = softmax_exact(&xp);
    let mut worst = 0.0f64;
    for (a, b) in y.iter().zip(&yp) {
        if *a > 0.0 {
            worst = worst.max((b - a).abs() / a);
        }
    }
    worst / max_delta
}

/// The paper's theoretical bound on `η_i` (the intermediate quantity of
/// eq. (10)): `|η_i| <= max_k |e^{δ_k - δ_i} - 1|`.
pub fn eta_bound(delta_bar: f64) -> f64 {
    (2.0 * delta_bar).exp_m1()
}

/// Run `trials` random amplification trials over dimension `n` and return
/// the maximum observed factor. The paper's claim: this never exceeds
/// 11/2 (for small `δ̄`), *regardless of n*.
pub fn max_amplification(seed: u64, n: usize, delta_bar: f64, trials: usize) -> f64 {
    let mut rng = Rng::new(seed);
    let mut worst = 0.0f64;
    for _ in 0..trials {
        let x: Vec<f64> = (0..n).map(|_| rng.range(-5.0, 5.0)).collect();
        worst = worst.max(amplification_trial(&mut rng, &x, delta_bar));
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_exact_normalizes() {
        let y = softmax_exact(&[1.0, 2.0, 3.0]);
        assert!((y.iter().sum::<f64>() - 1.0).abs() < 1e-14);
        assert!(y[2] > y[1] && y[1] > y[0]);
    }

    #[test]
    fn amplification_below_eleven_halves() {
        // Paper eq. (11): the relative output error is <= 5.5 max|δ|.
        for n in [2usize, 10, 100, 1000] {
            let worst = max_amplification(42, n, 1e-3, 50);
            assert!(
                worst <= 5.5,
                "n={n}: observed amplification {worst} exceeds 11/2"
            );
        }
    }

    #[test]
    fn amplification_roughly_two_for_small_deltas() {
        // The first-order constant is ~2 (e^{δ_k - δ_i} - 1 ~ 2δ̄): observed
        // factors should sit near 2, comfortably under the rigorous 5.5.
        let worst = max_amplification(7, 50, 1e-6, 200);
        assert!(worst <= 2.1, "observed {worst}");
        assert!(worst >= 0.5, "degenerate trial set ({worst})");
    }

    #[test]
    fn dimension_independence() {
        // The bound does not grow with n (the paper stresses this).
        let w10 = max_amplification(11, 10, 1e-4, 100);
        let w1000 = max_amplification(11, 1000, 1e-4, 20);
        assert!(w1000 <= w10 * 1.5 + 0.5, "n=1000 ({w1000}) vs n=10 ({w10})");
    }

    #[test]
    fn eta_bound_monotone() {
        assert!(eta_bound(0.0) == 0.0);
        assert!(eta_bound(1e-3) < eta_bound(1e-2));
        assert!((eta_bound(1e-6) - 2e-6).abs() < 1e-11);
    }
}
