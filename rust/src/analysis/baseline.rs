//! Baselines the experiments compare CAA against.
//!
//! * **IA-only** (`ia_only_class`): plain interval arithmetic without error
//!   bounds — the enclosure distance between the rounded and ideal range is
//!   the only error estimate it can give. This is what a naive rigorous
//!   analysis looks like and it is dramatically looser than CAA.
//! * **Sampling** (`sampling_estimate`): the non-rigorous "typical study"
//!   the paper's introduction describes — run the network at emulated
//!   precision k on test samples and report the worst observed deviation.
//!   It *under*-estimates (no guarantee), bracketing CAA from below.

use super::{AnalysisConfig, ClassAnalysis};
use crate::caa::Caa;
use crate::coordinator::with_worker_scratch;
use crate::model::Model;
use crate::plan::{Arena, Plan};
use crate::quant::{unit_roundoff, EmulatedFp};
use crate::tensor::EmuCtx;
use crate::util::Stopwatch;
use anyhow::{bail, Result};

/// IA-only analysis of one class: bounds derived solely from the distance
/// between the rounded and ideal enclosures, in units of u (evaluated at
/// `u_max`, the loosest covered precision).
pub fn ia_only_class(
    model: &Model,
    cfg: &AnalysisConfig,
    class: usize,
    sample: &[f64],
) -> Result<ClassAnalysis> {
    let sw = Stopwatch::start();
    let plan = Plan::for_analysis(model)?;
    let ctx = cfg.ctx.clone().ia_only();
    let input = super::caa_input_cfg(
        &ctx,
        plan.input_shape(),
        sample,
        cfg.input_radius,
        cfg.exact_inputs,
    );
    with_worker_scratch(|arena: &mut Arena<Caa>| {
        let outs = plan.execute::<Caa>(&ctx, input.data(), arena)?;
        let max_abs_u = outs
            .iter()
            .map(|o| ia_abs_estimate_u(o, ctx.u_max))
            .fold(0.0f64, f64::max);
        let max_rel_u = outs
            .iter()
            .map(|o| ia_rel_estimate_u(o, ctx.u_max))
            .fold(0.0f64, f64::max);
        let predicted = crate::caa::argmax_fp(outs);
        Ok(ClassAnalysis {
            class,
            max_abs_u,
            max_rel_u,
            top1_rel_u: ia_rel_estimate_u(&outs[predicted], ctx.u_max),
            predicted,
            ambiguous: outs.len() > 1 && crate::caa::argmax_ambiguous(outs),
            secs: sw.secs(),
        })
    })
}

/// Absolute error estimate available to a *single-interval* IA analysis,
/// in units of u. A plain IA tool keeps one enclosure per quantity — it
/// cannot separate the input data range from the accumulated rounding
/// error (the paper's motivation for CAA) — so the only sound error claim
/// it can make is the half-width of the final enclosure.
pub fn ia_abs_estimate_u(o: &Caa, u_max: f64) -> f64 {
    let r = o.rounded();
    if !r.is_finite() {
        return f64::INFINITY;
    }
    (r.width() / 2.0) / u_max
}

/// Relative error estimate from ranges alone (distance over mignitude).
pub fn ia_rel_estimate_u(o: &Caa, u_max: f64) -> f64 {
    let mig = o.ideal().mig();
    if mig == 0.0 {
        return f64::INFINITY;
    }
    ia_abs_estimate_u(o, u_max) / mig
}

/// Micro-batch size [`sampling_estimate`] drives the batched executor
/// with: big enough to amortize step dispatch and overlap the f64
/// reference's accumulation chains, small enough that the emulated-k
/// arena stays cache-resident.
const SAMPLING_BATCH: usize = 32;

/// Observed worst-case deviation of emulated precision-k runs from the f64
/// reference over a set of samples. Returns `(max_abs, max_rel)` in units
/// of `u = 2^(1-k)` — directly comparable to CAA bounds (which must
/// dominate it: CAA >= observed, always).
///
/// This is the hottest sampling loop in the experiments, so both passes
/// run through [`Plan::execute_batch`] in chunks of up to
/// `SAMPLING_BATCH` (32) samples: one plan drive per chunk per
/// arithmetic instead of one per sample. Per-sample values — and
/// therefore the returned maxima — are bit-identical to the per-sample
/// loop this replaces (the batched executor's per-sample-identity
/// contract).
pub fn sampling_estimate(
    model: &Model,
    k: u32,
    samples: &[Vec<f64>],
) -> Result<(f64, f64)> {
    let u = unit_roundoff(k);
    let ec = EmuCtx { k };
    // Unfused plan: the witness must execute the very computation the
    // analysis covers (batch-norm folding would change its rounding).
    let plan = Plan::unfused(model)?;
    let n = plan.input_len();
    let mut ref_arena = Arena::new();
    let mut emu_arena = Arena::new();
    let mut max_abs = 0.0f64;
    let mut max_rel = 0.0f64;
    let mut flat: Vec<f64> = Vec::with_capacity(SAMPLING_BATCH * n);
    let mut xe: Vec<EmulatedFp> = Vec::with_capacity(SAMPLING_BATCH * n);
    for chunk in samples.chunks(SAMPLING_BATCH) {
        flat.clear();
        for s in chunk {
            if s.len() != n {
                bail!(
                    "sampling_estimate: sample has {} values, model '{}' expects {n}",
                    s.len(),
                    model.name
                );
            }
            flat.extend_from_slice(s);
        }
        let b = chunk.len();
        let yr = plan.execute_batch::<f64>(&(), &flat, b, &mut ref_arena)?;
        xe.clear();
        xe.extend(flat.iter().map(|&v| EmulatedFp::new(v, k)));
        let ye = plan.execute_batch::<EmulatedFp>(&ec, &xe, b, &mut emu_arena)?;
        for (r, e) in yr.iter().zip(ye) {
            let d = (e.v - r).abs();
            max_abs = max_abs.max(d / u);
            if *r != 0.0 {
                max_rel = max_rel.max(d / r.abs() / u);
            }
        }
    }
    Ok((max_abs, max_rel))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze_class;
    use crate::model::zoo;
    use crate::util::Rng;

    #[test]
    fn sampling_below_caa_bound() {
        // The rigor sandwich: observed <= CAA for every sample and k.
        let m = zoo::tiny_mlp(5);
        let mut rng = Rng::new(2);
        let samples: Vec<Vec<f64>> = (0..8)
            .map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        for k in [8u32, 12, 16] {
            let (obs_abs, _obs_rel) = sampling_estimate(&m, k, &samples).unwrap();
            for s in &samples {
                let caa = analyze_class(&m, &AnalysisConfig::default(), 0, s).unwrap();
                assert!(
                    caa.max_abs_u >= 0.0 && caa.max_abs_u.is_finite(),
                    "CAA bound must exist for the MLP"
                );
                // The per-sample CAA bound dominates that sample's own
                // deviation; the dataset max is checked against the max
                // bound.
                let _ = obs_abs;
            }
            let worst_bound = samples
                .iter()
                .map(|s| {
                    analyze_class(&m, &AnalysisConfig::default(), 0, s)
                        .unwrap()
                        .max_abs_u
                })
                .fold(0.0f64, f64::max);
            assert!(
                worst_bound >= obs_abs,
                "k={k}: observed {obs_abs} exceeds rigorous bound {worst_bound}"
            );
        }
    }

    #[test]
    fn batched_sampling_estimate_matches_per_sample_loop_bitwise() {
        // The batched rewrite must reproduce the pre-batching per-sample
        // loop bit for bit — including on graph (residual) topologies and
        // with a sample count that is not a multiple of the batch size.
        let m = zoo::residual_cnn(8);
        let mut rng = Rng::new(4);
        let n: usize = m.input_shape.iter().product();
        let samples: Vec<Vec<f64>> = (0..37)
            .map(|_| (0..n).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        let k = 10u32;
        let (abs_b, rel_b) = sampling_estimate(&m, k, &samples).unwrap();

        let u = unit_roundoff(k);
        let ec = EmuCtx { k };
        let plan = Plan::unfused(&m).unwrap();
        let mut ref_arena = Arena::new();
        let mut emu_arena = Arena::new();
        let mut max_abs = 0.0f64;
        let mut max_rel = 0.0f64;
        for s in &samples {
            let yr = plan.execute::<f64>(&(), s, &mut ref_arena).unwrap();
            let xe: Vec<EmulatedFp> = s.iter().map(|&v| EmulatedFp::new(v, k)).collect();
            let ye = plan.execute::<EmulatedFp>(&ec, &xe, &mut emu_arena).unwrap();
            for (r, e) in yr.iter().zip(ye) {
                let d = (e.v - r).abs();
                max_abs = max_abs.max(d / u);
                if *r != 0.0 {
                    max_rel = max_rel.max(d / r.abs() / u);
                }
            }
        }
        assert_eq!(abs_b.to_bits(), max_abs.to_bits(), "abs estimate drifted");
        assert_eq!(rel_b.to_bits(), max_rel.to_bits(), "rel estimate drifted");
    }

    #[test]
    fn ia_estimates_infinite_when_range_unbounded() {
        let ctx = crate::caa::Ctx::new();
        let o = Caa::make(
            &ctx,
            0.0,
            crate::interval::Interval::new(-1.0, 1.0),
            crate::interval::Interval::ENTIRE,
            f64::INFINITY,
            f64::INFINITY,
        );
        assert!(ia_abs_estimate_u(&o, ctx.u_max).is_infinite());
        assert!(ia_rel_estimate_u(&o, ctx.u_max).is_infinite());
    }
}
