//! **Mixed-precision analysis and tuning** — the paper's §VI future-work
//! item: "removing the global u and parameterizing the error analysis with
//! the input/output precision".
//!
//! A mixed assignment gives every layer its own format `k_ℓ`
//! (`u_ℓ = 2^(1-k_ℓ)`). The analysis runs layer by layer in the layer's
//! own unit; at each format boundary the carried bounds are *rescaled*
//! into the next layer's unit (`δ̄' = δ̄ · u_ℓ / u_{ℓ+1}` — exact algebra,
//! rounded up) and the store-and-convert rounding of the boundary itself
//! (one ½-ulp relative error in the destination format) is charged.
//!
//! Unlike the uniform analysis, a mixed run is *not* parametric in u: it
//! certifies one concrete assignment. [`tune_mixed`] searches greedily for
//! a cheap assignment: starting from a certified uniform k, it walks the
//! layers and lowers each `k_ℓ` as far as the certification margin allows.
//!
//! Assignments are **per layer, in declaration order** (`ks[l]` is layer
//! `l`'s format) on sequential and graph models alike; the drivers map
//! each unfused step to its layer through the step's provenance
//! (`layer_range`), so a graph model whose JSON listing order differs
//! from the topological evaluation order still gets every layer at its
//! own declared format. Format boundaries are charged per buffer read:
//! when a step consumes a value produced in a different format, the
//! carried bounds are rescaled in place in that value's buffer and the
//! conversion rounding is charged. A skip value read by consumers in
//! several formats is rescaled at each transition, charging the chain of
//! conversions it would really undergo — an over-approximation across
//! diverging branches (sound: bounds only grow; per-edge maps are a
//! ROADMAP follow-on).

use super::{caa_input_cfg, AnalysisConfig, Margins};
use crate::caa::{badd, bmul, Caa, Ctx, RND_BASIC};
use crate::data::Dataset;
use crate::model::Model;
use crate::plan::{Arena, Fusion, Plan};
use crate::quant::{round_to_precision, unit_roundoff};
use anyhow::{bail, Result};

/// Result of a mixed-precision analysis over one assignment.
#[derive(Clone, Debug)]
pub struct MixedAnalysis {
    /// Per-layer mantissa widths.
    pub ks: Vec<u32>,
    /// Max absolute output error bound, **absolute** (not in units of u —
    /// a mixed run has no single u).
    pub max_abs: f64,
    /// Max relative output error bound, dimensionless.
    pub max_rel: f64,
    /// Whether every class representative kept an unambiguous argmax and
    /// met the p* margins.
    pub certified: bool,
}

/// Convert a value's bounds from unit `u_from` to unit `u_to` and charge
/// the format-conversion rounding (storing into the `u_to` format).
fn rescale(v: &Caa, u_from: f64, u_to: f64) -> Caa {
    let ratio = u_from / u_to;
    // Bounds are nonnegative; multiply rounded up. The conversion itself
    // is one rounding in the destination format: ε += 1/2, δ += |q|/2.
    let abs = badd(bmul(v.abs_bound(), ratio), bmul(RND_BASIC, v.ideal().mag()));
    let rel = badd(bmul(v.rel_bound(), ratio), RND_BASIC);
    Caa::from_parts(
        &Ctx::with_u_max(u_to),
        v.fp(),
        v.ideal(),
        v.rounded(),
        abs,
        rel,
    )
}

/// The shared per-entry rule: every `k` must be a real mantissa width.
fn validate_ks_range(ks: &[u32]) -> Result<()> {
    if let Some(&bad) = ks.iter().find(|&&k| !(2..=53).contains(&k)) {
        bail!("invalid per-layer precision {bad}");
    }
    Ok(())
}

/// Validate an assignment against a model (shared by analysis and tuning).
pub fn validate_assignment(model: &Model, ks: &[u32]) -> Result<()> {
    if ks.len() != model.layers.len() {
        bail!(
            "assignment has {} entries for {} layers",
            ks.len(),
            model.layers.len()
        );
    }
    validate_ks_range(ks)
}

/// Validate an assignment against an **unfused** plan (1 step = 1 layer;
/// `ks[l]` is the format of *layer* `l` in declaration order — steps find
/// their layer through provenance, see [`step_k`]).
fn validate_assignment_plan(plan: &Plan, ks: &[u32]) -> Result<()> {
    if plan.fusion() != Fusion::None {
        bail!("mixed-precision analysis needs an unfused plan (Plan::unfused)");
    }
    if ks.len() != plan.steps().len() {
        bail!(
            "assignment has {} entries for {} layers",
            ks.len(),
            plan.steps().len()
        );
    }
    validate_ks_range(ks)
}

/// The format of step `i` of an unfused plan under a per-layer assignment:
/// an unfused step covers exactly one layer, recorded in its provenance,
/// so this holds for any topological ordering of a graph model.
fn step_k(plan: &Plan, ks: &[u32], i: usize) -> u32 {
    ks[plan.steps()[i].layer_range.0]
}

/// Analyze one sample under a per-layer precision assignment. Returns the
/// output values in the *last* layer's unit. Convenience wrapper that
/// compiles a throwaway unfused plan; see [`analyze_sample_mixed_plan`].
pub fn analyze_sample_mixed(
    model: &Model,
    cfg: &AnalysisConfig,
    ks: &[u32],
    sample: &[f64],
) -> Result<Vec<Caa>> {
    analyze_sample_mixed_plan(&Plan::unfused(model)?, cfg, ks, sample)
}

/// [`analyze_sample_mixed`] against a precompiled **unfused** plan: steps
/// map 1:1 to layers, so per-layer format boundaries stay addressable.
/// The driver interleaves the plan's step execution with the boundary
/// rescaling + conversion charge.
pub fn analyze_sample_mixed_plan(
    plan: &Plan,
    cfg: &AnalysisConfig,
    ks: &[u32],
    sample: &[f64],
) -> Result<Vec<Caa>> {
    validate_assignment_plan(plan, ks)?;
    // The input is embedded in the format of the first *executed* layer.
    let u0 = unit_roundoff(step_k(plan, ks, 0));
    let ctx0 = Ctx::with_u_max(u0);
    let input =
        caa_input_cfg(&ctx0, plan.input_shape(), sample, cfg.input_radius, cfg.exact_inputs);
    // Reuse this thread's arena: the tuning loop calls this O(layers ×
    // k-range × classes) times, and only the (small) output is copied out.
    crate::coordinator::with_worker_scratch(|arena: &mut Arena<Caa>| {
        arena.load_input(plan, input.data());
        // Format currently held by each pool buffer; the input starts in
        // the first step's format (matching the embedding context above).
        let mut buf_u = vec![u0; plan.buffer_count()];
        for i in 0..plan.steps().len() {
            let u = unit_roundoff(step_k(plan, ks, i));
            let step = &plan.steps()[i];
            for &b in &step.inputs {
                if buf_u[b] != u {
                    // Format boundary: rescale bounds + charge the
                    // conversion, in place in the value's buffer.
                    let from = buf_u[b];
                    for v in arena.buffer_mut(b) {
                        *v = rescale(v, from, u);
                    }
                    buf_u[b] = u;
                }
            }
            let ctx = Ctx::with_u_max(u);
            plan.execute_step::<Caa>(i, &ctx, arena);
            buf_u[step.out] = u;
        }
        Ok(arena.buffer(plan.output_buf()).to_vec())
    })
}

/// Analyze all class representatives under an assignment and check the
/// p*-margin certification. Convenience wrapper compiling a throwaway
/// unfused plan; the tuning loop uses [`analyze_mixed_plan`].
pub fn analyze_mixed(
    model: &Model,
    data: &Dataset,
    cfg: &AnalysisConfig,
    ks: &[u32],
) -> Result<MixedAnalysis> {
    // The plan variant re-validates against the (1:1) step list, so no
    // model-level pre-check is needed here.
    analyze_mixed_plan(&Plan::unfused(model)?, data, cfg, ks)
}

/// [`analyze_mixed`] against a precompiled unfused plan.
pub fn analyze_mixed_plan(
    plan: &Plan,
    data: &Dataset,
    cfg: &AnalysisConfig,
    ks: &[u32],
) -> Result<MixedAnalysis> {
    validate_assignment_plan(plan, ks)?;
    let n_steps = plan.steps().len();
    if n_steps == 0 {
        bail!("mixed-precision analysis needs at least one layer");
    }
    let reps = if data.labels.is_empty() {
        vec![(0usize, 0usize)]
    } else {
        data.class_representatives()
    };
    let margins = Margins::new(cfg.p_star)?;
    // The last step in topological order is the output layer (liveness
    // validation makes every layer an ancestor of the sink).
    let u_out = unit_roundoff(step_k(plan, ks, n_steps - 1));
    let mut max_abs = 0.0f64;
    let mut max_rel = 0.0f64;
    let mut certified = true;
    for (_, idx) in reps {
        let out = analyze_sample_mixed_plan(plan, cfg, ks, &data.inputs[idx])?;
        for o in &out {
            max_abs = max_abs.max(o.abs_bound() * u_out);
            max_rel = max_rel.max(o.rel_bound() * u_out);
        }
        let ok_abs = out.iter().all(|o| o.abs_bound() * u_out <= margins.abs_margin());
        let ok_rel = out.iter().all(|o| o.rel_bound() * u_out <= margins.rel_margin());
        if !(ok_abs || ok_rel) {
            certified = false;
        }
    }
    Ok(MixedAnalysis { ks: ks.to_vec(), max_abs, max_rel, certified })
}

/// Greedy mixed-precision tuning: start from a *certified* uniform
/// assignment (`k_uniform` everywhere) and, layer by layer, lower each
/// `k_ℓ` to the smallest value that keeps the whole assignment certified.
/// Returns the assignment (layers that tolerate nothing keep `k_uniform`).
pub fn tune_mixed(
    model: &Model,
    data: &Dataset,
    cfg: &AnalysisConfig,
    k_uniform: u32,
    k_floor: u32,
) -> Result<MixedAnalysis> {
    // One compile serves the entire greedy search (O(layers * k-range)
    // analyses).
    let plan = Plan::unfused(model)?;
    let n = plan.steps().len();
    let mut ks = vec![k_uniform; n];
    let base = analyze_mixed_plan(&plan, data, cfg, &ks)?;
    if !base.certified {
        bail!("uniform k = {k_uniform} does not certify; tune from a certified baseline");
    }
    for layer in 0..n {
        let mut best = ks[layer];
        // Binary search would be possible; layer counts are small and the
        // cost model is monotone, so a simple downward walk is clearest.
        let mut k = ks[layer];
        while k > k_floor {
            k -= 1;
            ks[layer] = k;
            if analyze_mixed_plan(&plan, data, cfg, &ks)?.certified {
                best = k;
            } else {
                break;
            }
        }
        ks[layer] = best;
    }
    analyze_mixed_plan(&plan, data, cfg, &ks)
}

/// Emulated mixed-precision *execution* (witness for the analysis): runs
/// the model in f64 but rounds every step output (and the lifted
/// parameters) to the step's format — storage emulation with per-layer
/// formats. Driven step-by-step through an unfused plan, so it works on
/// sequential and graph models alike (each step rounds exactly its own
/// output buffer).
pub fn forward_mixed_emulated(model: &Model, ks: &[u32], sample: &[f64]) -> Result<Vec<f64>> {
    if ks.len() != model.layers.len() {
        bail!("assignment length mismatch");
    }
    let plan = Plan::unfused(model)?;
    if plan.steps().is_empty() {
        bail!("mixed-precision emulation needs at least one layer");
    }
    // Round the input into the first *executed* layer's format.
    let k_in = step_k(&plan, ks, 0);
    let rounded_input: Vec<f64> = sample.iter().map(|&v| round_to_precision(v, k_in)).collect();
    if rounded_input.len() != plan.input_len() {
        bail!("sample has {} values for input {:?}", rounded_input.len(), plan.input_shape());
    }
    let mut arena = Arena::new();
    arena.load_input(&plan, &rounded_input);
    for i in 0..plan.steps().len() {
        let k = step_k(&plan, ks, i);
        plan.execute_step::<f64>(i, &(), &mut arena);
        for v in arena.buffer_mut(plan.steps()[i].out) {
            *v = round_to_precision(*v, k);
        }
    }
    Ok(arena.buffer(plan.output_buf()).to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn small_setup() -> (Model, Dataset) {
        let m = zoo::tiny_mlp(42);
        let mut rng = Rng::new(1);
        let inputs: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        (m, Dataset { input_shape: vec![8], inputs, labels: vec![0, 1, 2] })
    }

    #[test]
    fn uniform_mixed_matches_uniform_analysis_scale() {
        // A mixed run with all layers at k must give bounds comparable to
        // the uniform analysis at u_max = 2^(1-k).
        let (m, data) = small_setup();
        let cfg = AnalysisConfig::default();
        let ks = vec![20u32; m.layers.len()];
        let mixed = analyze_mixed(&m, &data, &cfg, &ks).unwrap();
        assert!(mixed.max_abs.is_finite());

        let mut ucfg = cfg.clone();
        ucfg.ctx = Ctx::with_u_max(unit_roundoff(20));
        let uniform = super::super::analyze_model_impl(&m, &data, &ucfg).unwrap();
        let uniform_abs = uniform.max_abs_u * unit_roundoff(20);
        // No boundary conversions happen (single format), but input/ctx
        // bookkeeping differs slightly; same order of magnitude.
        assert!(mixed.max_abs <= uniform_abs * 4.0 + 1e-12);
        assert!(mixed.max_abs >= uniform_abs / 4.0);
    }

    #[test]
    fn rejects_bad_assignments() {
        let (m, data) = small_setup();
        let cfg = AnalysisConfig::default();
        assert!(analyze_mixed(&m, &data, &cfg, &[24, 24]).is_err()); // wrong len
        let bad = vec![1u32; m.layers.len()];
        assert!(analyze_mixed(&m, &data, &cfg, &bad).is_err()); // k too small
    }

    #[test]
    fn tuning_lowers_some_layer_and_stays_certified() {
        let (m, data) = small_setup();
        let mut cfg = AnalysisConfig::default();
        cfg.p_star = 0.60;
        // Find a certified uniform baseline first.
        let (k0, _) = super::super::certify_min_precision(&m, &data, &cfg, 6..=30)
            .unwrap()
            .expect("baseline certifies");
        let tuned = tune_mixed(&m, &data, &cfg, k0 + 2, 4).unwrap();
        assert!(tuned.certified);
        assert!(tuned.ks.iter().all(|&k| k <= k0 + 2));
        assert!(
            tuned.ks.iter().any(|&k| k < k0 + 2),
            "greedy tuning should lower at least one layer from {} ({:?})",
            k0 + 2,
            tuned.ks
        );
    }

    #[test]
    fn tuning_requires_certified_baseline() {
        let (m, data) = small_setup();
        let mut cfg = AnalysisConfig::default();
        cfg.p_star = 0.5001; // margin μ = 1e-4: hopeless at k = 8
        assert!(tune_mixed(&m, &data, &cfg, 8, 4).is_err());
    }

    #[test]
    fn mixed_bounds_dominate_emulated_mixed_runs() {
        // Soundness of the mixed path: emulated per-layer-format execution
        // must stay within the mixed CAA bounds.
        let (m, data) = small_setup();
        let cfg = AnalysisConfig::default();
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let ks: Vec<u32> = (0..m.layers.len())
                .map(|_| 10 + rng.below(14) as u32)
                .collect();
            for sample in &data.inputs {
                let bounds = analyze_sample_mixed(&m, &cfg, &ks, sample).unwrap();
                let emu = forward_mixed_emulated(&m, &ks, sample).unwrap();
                let reference = m
                    .forward::<f64>(&(), Tensor::new(m.input_shape.clone(), sample.clone()))
                    .unwrap();
                let u_out = unit_roundoff(*ks.last().unwrap());
                for i in 0..emu.len() {
                    let err = (emu[i] - reference.data()[i]).abs();
                    let bound = bounds[i].abs_bound() * u_out;
                    assert!(
                        err <= bound * (1.0 + 1e-9) + 1e-12,
                        "mixed ks={ks:?} output {i}: err {err:.3e} > bound {bound:.3e}"
                    );
                }
            }
        }
    }

    #[test]
    fn assignment_is_per_layer_even_when_listing_is_not_topological() {
        // Two structurally identical residual models sharing the same
        // weights, one listed topologically and one listed in reverse.
        // A per-layer assignment, permuted the same way, must produce
        // bit-identical emulated runs and bounds — i.e. `ks[l]` follows
        // the *layer*, not the topological step position.
        use crate::layers::Layer;
        use crate::model::{zoo, Graph, Model};
        let mut rng = crate::util::Rng::new(31);
        let d1 = zoo::dense(&mut rng, 4, 4);
        let d2 = zoo::dense(&mut rng, 4, 4);
        let d3 = zoo::dense(&mut rng, 4, 2);

        let wires = |names: &[&str], inbound: &[&[&str]]| Graph {
            names: names.iter().map(|s| s.to_string()).collect(),
            inbound: inbound
                .iter()
                .map(|ins| ins.iter().map(|s| s.to_string()).collect())
                .collect(),
            output: Some("d3".to_string()),
        };
        let topo_listed = Model {
            name: "topo".into(),
            input_shape: vec![4],
            layers: vec![d1.clone(), Layer::Relu, d2.clone(), Layer::Add, d3.clone()],
            graph: Some(wires(
                &["d1", "a1", "d2", "s", "d3"],
                &[&["input"], &["d1"], &["a1"], &["d2", "a1"], &["s"]],
            )),
        };
        let reverse_listed = Model {
            name: "reverse".into(),
            input_shape: vec![4],
            layers: vec![d3, Layer::Add, d2, Layer::Relu, d1],
            graph: Some(wires(
                &["d3", "s", "d2", "a1", "d1"],
                &[&["s"], &["d2", "a1"], &["a1"], &["d1"], &["input"]],
            )),
        };

        let ks_topo = vec![12u32, 14, 16, 18, 20];
        let ks_reverse: Vec<u32> = ks_topo.iter().rev().copied().collect();
        let sample = vec![0.3, -0.1, 0.7, 0.5];

        let ya = forward_mixed_emulated(&topo_listed, &ks_topo, &sample).unwrap();
        let yb = forward_mixed_emulated(&reverse_listed, &ks_reverse, &sample).unwrap();
        assert_eq!(ya.len(), yb.len());
        for (a, b) in ya.iter().zip(&yb) {
            assert_eq!(a.to_bits(), b.to_bits(), "emulated runs must agree bitwise");
        }

        let cfg = AnalysisConfig::default();
        let ba = analyze_sample_mixed(&topo_listed, &cfg, &ks_topo, &sample).unwrap();
        let bb = analyze_sample_mixed(&reverse_listed, &cfg, &ks_reverse, &sample).unwrap();
        for (a, b) in ba.iter().zip(&bb) {
            assert_eq!(a.abs_bound().to_bits(), b.abs_bound().to_bits());
            assert_eq!(a.rel_bound().to_bits(), b.rel_bound().to_bits());
        }
    }

    #[test]
    fn forward_mixed_emulated_rounds_each_layer() {
        let (m, _) = small_setup();
        let ks = vec![6u32; m.layers.len()];
        let sample: Vec<f64> = (0..8).map(|i| 0.1 + i as f64 * 0.05).collect();
        let out = forward_mixed_emulated(&m, &ks, &sample).unwrap();
        for v in &out {
            assert_eq!(round_to_precision(*v, 6), *v, "output not in k=6 format");
        }
    }
}
