//! The analysis engine — the paper's pipeline stages §IV–§VI over a
//! compiled [`crate::plan::Plan`].
//!
//! One [`analyze_class`] call is one CAA inference run: the sample is
//! embedded as CAA inputs ([`caa_input_cfg`]), executed through the
//! shared analysis plan (sequential or graph topology alike), and the
//! output bounds are aggregated per class and per model
//! ([`ModelAnalysis`]). On top of the single parametric run sit:
//!
//! * [`margins`] (§IV): the `p*`-margin algebra turning output error
//!   bounds into the minimum safe precision;
//! * [`certify_min_precision`] (§V): the semi-automatic tailoring loop
//!   re-running the analysis at candidate `u_max = 2^(1-k)`;
//! * [`mixed`] (§VI): per-layer format assignments, boundary conversion
//!   charges, and greedy tuning;
//! * [`baseline`]: the IA-only and sampling baselines the experiments
//!   bracket CAA between, and [`softmax_theory`]: the paper's closed-form
//!   softmax bound checker.
//!
//! Callers go through [`crate::api::Session`]; the free functions here are
//! the engine the service layer drives.

pub mod baseline;
pub mod margins;
pub mod mixed;
pub mod softmax_theory;

pub use margins::{required_precision, validity_floor, Margins};

use crate::caa::{argmax_ambiguous, argmax_fp, Caa, Ctx};
use crate::coordinator::with_worker_scratch;
use crate::data::Dataset;
use crate::interval::Interval;
use crate::model::Model;
use crate::obs::{self, BoundProfile, BoundStep};
use crate::plan::{Arena, Plan};
use crate::tensor::Tensor;
use crate::util::Stopwatch;
use anyhow::Result;

/// Configuration for a model analysis.
#[derive(Clone, Debug)]
pub struct AnalysisConfig {
    /// CAA context (u_max and feature toggles).
    pub ctx: Ctx,
    /// Top-1 confidence floor for precision tailoring.
    pub p_star: f64,
    /// Radius of the input box around each representative (0 = point
    /// analysis; the paper widens inputs "with interval bounds for the
    /// inputs' ranges").
    pub input_radius: f64,
    /// Treat inputs as exactly representable in every analyzed format
    /// (no representation rounding): correct for integer pixel data
    /// (`[0, 255]` is exact for k >= 8 — the paper's image annotation) and
    /// for formal-verification queries at representable points (Pendulum).
    /// Keep `false` for arbitrary real-valued inputs.
    pub exact_inputs: bool,
}

impl Default for AnalysisConfig {
    fn default() -> Self {
        AnalysisConfig {
            ctx: Ctx::new(),
            p_star: 0.60,
            input_radius: 0.0,
            exact_inputs: false,
        }
    }
}

/// Analysis result for one class representative (one CAA inference run).
#[derive(Clone, Debug)]
pub struct ClassAnalysis {
    /// The class this representative belongs to.
    pub class: usize,
    /// Max absolute error bound over all output elements, units of u.
    pub max_abs_u: f64,
    /// Max relative error bound over all output elements, units of u
    /// (+inf when none exists, e.g. outputs straddling zero).
    pub max_rel_u: f64,
    /// Relative bound on the top-1 element only (the paper observes these
    /// stay much tighter than the non-top elements).
    pub top1_rel_u: f64,
    /// argmax of the fp trace.
    pub predicted: usize,
    /// Whether rounded ranges of distinct classes overlap (a
    /// misclassification cannot be excluded *within the analyzed u range*).
    pub ambiguous: bool,
    /// Wall-clock seconds this class's CAA run took.
    pub secs: f64,
}

/// Aggregated analysis of a model over all class representatives.
#[derive(Clone, Debug)]
pub struct ModelAnalysis {
    /// Name of the analyzed model.
    pub model_name: String,
    /// One entry per analyzed class representative.
    pub per_class: Vec<ClassAnalysis>,
    /// Worst absolute error bound over all classes, units of u.
    pub max_abs_u: f64,
    /// Worst relative error bound over all classes, units of u.
    pub max_rel_u: f64,
    /// Total wall-clock seconds of the analysis.
    pub total_secs: f64,
    /// Minimum precision that provably preserves the argmax at p*.
    pub required_k: Option<u32>,
    /// The confidence floor the margins were derived from.
    pub p_star: f64,
    /// The `u_max` the bounds are valid under.
    pub u_max: f64,
}

impl ModelAnalysis {
    /// Average seconds per class run (Table I's time column).
    pub fn secs_per_class(&self) -> f64 {
        if self.per_class.is_empty() {
            0.0
        } else {
            self.total_secs / self.per_class.len() as f64
        }
    }
}

/// Build the CAA input tensor for a sample: each pixel becomes an input
/// quantity with an optional box of radius `r` around it, exact or rounded
/// per `exact`.
pub fn caa_input_cfg(
    ctx: &Ctx,
    shape: &[usize],
    sample: &[f64],
    r: f64,
    exact: bool,
) -> Tensor<Caa> {
    let data = sample
        .iter()
        .map(|&v| {
            let range = if r > 0.0 {
                Interval::new(v - r, v + r)
            } else {
                Interval::point(v)
            };
            if exact {
                Caa::input_exact(range, v)
            } else {
                Caa::input(ctx, range, v)
            }
        })
        .collect();
    Tensor::new(shape.to_vec(), data)
}

/// [`caa_input_cfg`] with rounded (non-exact) inputs.
pub fn caa_input(ctx: &Ctx, shape: &[usize], sample: &[f64], r: f64) -> Tensor<Caa> {
    caa_input_cfg(ctx, shape, sample, r, false)
}

/// Analyze one class representative: run the model once under CAA and
/// aggregate the output bounds. Convenience wrapper that compiles a
/// throwaway analysis [`Plan`]; loops should compile once and call
/// [`analyze_class_with_plan`] (as the [`crate::api::Session`] paths do).
pub fn analyze_class(
    model: &Model,
    cfg: &AnalysisConfig,
    class: usize,
    sample: &[f64],
) -> Result<ClassAnalysis> {
    let plan = Plan::for_analysis(model)?;
    analyze_class_with_plan(&plan, cfg, class, sample)
}

/// Analyze one class representative against a precompiled analysis plan
/// (the hot path: shapes are pre-resolved, and the executor reuses this
/// worker thread's arena, so the CAA run itself is allocation-free at the
/// tensor level).
pub fn analyze_class_with_plan(
    plan: &Plan,
    cfg: &AnalysisConfig,
    class: usize,
    sample: &[f64],
) -> Result<ClassAnalysis> {
    let sw = Stopwatch::start();
    let input = caa_input_cfg(
        &cfg.ctx,
        plan.input_shape(),
        sample,
        cfg.input_radius,
        cfg.exact_inputs,
    );
    with_worker_scratch(|arena: &mut Arena<Caa>| {
        let outs = if obs::tracing() {
            // Bound probe: the same step loop `execute` runs, with the
            // per-step bound widths recorded along the way — the final
            // output buffer (and thus this class's result) is bitwise
            // identical to the untraced run.
            let profile = probe_walk(plan, &cfg.ctx, input.data(), arena)?;
            obs::registry().record_bounds(profile);
            arena.bufs[plan.output_buf()].as_slice()
        } else {
            plan.execute::<Caa>(&cfg.ctx, input.data(), arena)?
        };
        let max_abs_u = outs.iter().map(|o| o.abs_bound()).fold(0.0f64, f64::max);
        let max_rel_u = outs.iter().map(|o| o.rel_bound()).fold(0.0f64, f64::max);
        let predicted = argmax_fp(outs);
        let top1_rel_u = outs[predicted].rel_bound();
        let ambiguous = outs.len() > 1 && argmax_ambiguous(outs);
        Ok(ClassAnalysis {
            class,
            max_abs_u,
            max_rel_u,
            top1_rel_u,
            predicted,
            ambiguous,
            secs: sw.secs(),
        })
    })
}

/// Step through the plan under CAA recording the widest
/// absolute/relative bound in each step's output buffer. This *is* the
/// `Plan::execute` step loop (`load_input` + `execute_step` in order),
/// so the arena's final output buffer is bitwise identical to an
/// untraced execution — the probe only reads bounds between steps.
fn probe_walk(
    plan: &Plan,
    ctx: &Ctx,
    input: &[Caa],
    arena: &mut Arena<Caa>,
) -> Result<BoundProfile> {
    anyhow::ensure!(
        input.len() == plan.input_len(),
        "plan '{}' expects {} input values, got {}",
        plan.model_name(),
        plan.input_len(),
        input.len()
    );
    arena.load_input(plan, input);
    let mut steps = Vec::with_capacity(plan.steps().len());
    for idx in 0..plan.steps().len() {
        let sw = Stopwatch::start();
        plan.execute_step::<Caa>(idx, ctx, arena);
        let secs = sw.secs();
        let step = &plan.steps()[idx];
        let buf = &arena.bufs[step.out];
        steps.push(BoundStep {
            index: idx,
            kind: step.kind.name(),
            out_len: buf.len(),
            abs_u: buf.iter().map(|o| o.abs_bound()).fold(0.0f64, f64::max),
            rel_u: buf.iter().map(|o| o.rel_bound()).fold(0.0f64, f64::max),
            secs,
        });
    }
    Ok(BoundProfile { model: plan.model_name().to_string(), steps })
}

/// The per-layer error-bound profile of one CAA run — the paper's
/// signature per-step shape (convolutions widen the relative bound,
/// well-conditioned activations like ReLU/softmax re-contract it),
/// printed by `rigor profile` next to wall-clock cost. Prefer an
/// **unfused** plan ([`Plan::unfused`]) so activation steps appear as
/// their own rows instead of disappearing into fused conv/dense steps.
/// The profile is also recorded into the [`crate::obs`] registry.
pub fn bound_profile_with_plan(
    plan: &Plan,
    cfg: &AnalysisConfig,
    sample: &[f64],
) -> Result<BoundProfile> {
    let input = caa_input_cfg(
        &cfg.ctx,
        plan.input_shape(),
        sample,
        cfg.input_radius,
        cfg.exact_inputs,
    );
    with_worker_scratch(|arena: &mut Arena<Caa>| {
        let profile = probe_walk(plan, &cfg.ctx, input.data(), arena)?;
        obs::registry().record_bounds(profile.clone());
        Ok(profile)
    })
}

/// The (class, sample-index) jobs an analysis of `data` consists of: one
/// representative per class, or a single job over the input box for
/// regression data (Pendulum) with no labels.
pub(crate) fn representatives(data: &Dataset) -> Vec<(usize, usize)> {
    if data.labels.is_empty() {
        vec![(0usize, 0usize)]
    } else {
        data.class_representatives()
    }
}

/// Analyze a model over one representative per class (the paper's
/// workflow: "we run the resulting program for all possible classes ...
/// only for one representative of the class").
#[deprecated(
    since = "0.2.0",
    note = "use `api::Session::run` with an `api::AnalysisRequest` (ExecMode::Serial)"
)]
pub fn analyze_model(model: &Model, data: &Dataset, cfg: &AnalysisConfig) -> Result<ModelAnalysis> {
    analyze_model_impl(model, data, cfg)
}

/// Serial analysis loop — the engine behind the deprecated
/// [`analyze_model`] shim and the [`crate::api`] service layer.
pub(crate) fn analyze_model_impl(
    model: &Model,
    data: &Dataset,
    cfg: &AnalysisConfig,
) -> Result<ModelAnalysis> {
    let sw = Stopwatch::start();
    let plan = Plan::for_analysis(model)?;
    let reps = representatives(data);
    let mut per_class = Vec::with_capacity(reps.len());
    for (class, idx) in reps {
        per_class.push(analyze_class_with_plan(&plan, cfg, class, &data.inputs[idx])?);
    }
    Ok(aggregate(model, cfg, per_class, sw.secs()))
}

/// Combine per-class results (exposed so the coordinator can fan the
/// per-class jobs out and aggregate afterwards).
pub fn aggregate(
    model: &Model,
    cfg: &AnalysisConfig,
    per_class: Vec<ClassAnalysis>,
    total_secs: f64,
) -> ModelAnalysis {
    let max_abs_u = per_class.iter().map(|c| c.max_abs_u).fold(0.0f64, f64::max);
    let max_rel_u = per_class.iter().map(|c| c.max_rel_u).fold(0.0f64, f64::max);
    let required_k = Margins::new(cfg.p_star).ok().and_then(|m| {
        margins::required_precision(max_abs_u, max_rel_u, m, cfg.ctx.u_max)
    });
    ModelAnalysis {
        model_name: model.name.clone(),
        per_class,
        max_abs_u,
        max_rel_u,
        total_secs,
        required_k,
        p_star: cfg.p_star,
        u_max: cfg.ctx.u_max,
    }
}

/// The paper's semi-automatic precision-tailoring loop: "the output error
/// bounds can then be used to tailor the DNN's actual FP arithmetic,
/// determining the value of u such that the required accuracy bounds are
/// still met" (§V). The single-run analysis yields bounds valid for all
/// `u <= u_max`, but for deep/wide networks the bounds at a coarse `u_max`
/// can be vacuous even though a *finer* precision is certifiable — so we
/// re-run the analysis per candidate `k` with `u_max = 2^(1-k)` and return
/// the smallest `k` whose own bounds satisfy the p* margins.
pub fn certify_min_precision(
    model: &Model,
    data: &Dataset,
    base: &AnalysisConfig,
    k_range: std::ops::RangeInclusive<u32>,
) -> Result<Option<(u32, ModelAnalysis)>> {
    for k in k_range {
        let mut cfg = base.clone();
        cfg.ctx.u_max = 2f64.powi(1 - k as i32);
        let a = analyze_model_impl(model, data, &cfg)?;
        if let Some(rk) = a.required_k {
            if rk <= k {
                return Ok(Some((k, a)));
            }
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    // The unit tests exercise the engine loop directly (the public shim is
    // deprecated in favor of `api::Session`).
    use super::analyze_model_impl as analyze_model;
    use crate::data::synthetic;
    use crate::model::zoo;
    use crate::util::Rng;

    #[test]
    fn analyze_tiny_mlp() {
        let m = zoo::tiny_mlp(42);
        let mut rng = Rng::new(1);
        let inputs: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        let data = Dataset {
            input_shape: vec![8],
            inputs,
            labels: vec![0, 1, 2],
        };
        let a = analyze_model(&m, &data, &AnalysisConfig::default()).unwrap();
        assert_eq!(a.per_class.len(), 3);
        assert!(a.max_abs_u.is_finite());
        assert!(a.max_abs_u > 0.0);
        assert!(a.required_k.is_some());
        assert!(a.total_secs >= 0.0);
    }

    #[test]
    fn pendulum_regression_has_abs_but_maybe_no_rel() {
        let m = zoo::tiny_pendulum(7);
        let data = synthetic::pendulum_grid(3);
        let mut cfg = AnalysisConfig::default();
        cfg.input_radius = 0.0;
        let a = analyze_model(&m, &data, &cfg).unwrap();
        assert_eq!(a.per_class.len(), 1);
        assert!(a.max_abs_u.is_finite(), "tanh net must carry an absolute bound");
    }

    #[test]
    fn pendulum_whole_box_analysis() {
        // The paper's Pendulum run analyzes the whole input box [-6,6]^2 in
        // one shot: a single input sample with radius 6 around 0.
        let m = zoo::tiny_pendulum(7);
        let data = Dataset {
            input_shape: vec![2],
            inputs: vec![vec![0.0, 0.0]],
            labels: vec![],
        };
        let mut cfg = AnalysisConfig::default();
        cfg.input_radius = 6.0;
        let a = analyze_model(&m, &data, &cfg).unwrap();
        assert!(a.max_abs_u.is_finite());
        // Output interval spans zero for a generic net => no relative bound
        // (the paper reports "-" for Pendulum's relative error).
        // (Not asserted: depends on random weights.)
    }

    #[test]
    fn input_radius_widens_bounds() {
        let m = zoo::tiny_mlp(42);
        let sample: Vec<f64> = (0..8).map(|i| 0.1 * i as f64).collect();
        let point = analyze_class(&m, &AnalysisConfig::default(), 0, &sample).unwrap();
        let mut cfg = AnalysisConfig::default();
        cfg.input_radius = 0.05;
        let boxed = analyze_class(&m, &cfg, 0, &sample).unwrap();
        assert!(
            boxed.max_abs_u >= point.max_abs_u,
            "box analysis must not tighten bounds"
        );
    }

    #[test]
    fn certify_finds_a_precision_for_small_mlp() {
        let m = zoo::tiny_mlp(42);
        let mut rng = Rng::new(1);
        let inputs: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..8).map(|_| rng.range(0.0, 1.0)).collect())
            .collect();
        let data = Dataset { input_shape: vec![8], inputs, labels: vec![0, 1, 2] };
        let cfg = AnalysisConfig::default();
        let got = certify_min_precision(&m, &data, &cfg, 4..=30).unwrap();
        let (k, a) = got.expect("small MLP must certify somewhere in [4, 30]");
        assert!(a.required_k.unwrap() <= k);
        // Certification is monotone: a looser k also certifies.
        let mut cfg2 = cfg.clone();
        cfg2.ctx.u_max = 2f64.powi(1 - (k as i32) - 4);
        let a2 = analyze_model(&m, &data, &cfg2).unwrap();
        assert!(a2.required_k.unwrap() <= k + 4);
    }

    #[test]
    fn ia_only_much_looser_than_caa() {
        // The A-caa-vs-ia ablation in miniature: on a *ranged* input
        // (the pendulum verification box), a single-interval IA analysis
        // cannot separate the data range from the rounding error, so its
        // error estimate is dominated by the range itself; CAA keeps a
        // small absolute bound.
        let m = zoo::tiny_pendulum(7);
        let mut cfg = AnalysisConfig::default();
        cfg.input_radius = 6.0;
        cfg.exact_inputs = true; // verification queries at representable points
        let caa = analyze_class(&m, &cfg, 0, &[0.0, 0.0]).unwrap();
        let ia = baseline::ia_only_class(&m, &cfg, 0, &[0.0, 0.0]).unwrap();
        assert!(caa.max_abs_u.is_finite());
        // The IA estimate is floored by the *data range* of the output
        // (tanh compresses it to ~[-1,1] here, so the gap is a small
        // multiple; on wide-range outputs it is orders of magnitude — see
        // benches/ablation_arith.rs).
        assert!(
            ia.max_abs_u > 2.0 * caa.max_abs_u,
            "IA-only ({}) must be looser than CAA ({})",
            ia.max_abs_u,
            caa.max_abs_u
        );
    }
}
