//! Error margins and precision tailoring (paper §IV).
//!
//! Given external knowledge that the top-1 confidence is at least
//! `p* > 0.5` on all possible inputs, each output element may absorb an
//! absolute FP error `μ = p* - 1/2` and a relative FP error
//! `ν = (2p* - 1)/(2p* + 1)` without the argmax — the predicted class —
//! ever flipping. Combining the margins with the CAA output bounds
//! (expressed in units of `u = 2^(1-k)`) yields the minimum safe
//! precision `k`.

/// Classification error margins derived from a top-1 confidence floor.
#[derive(Clone, Copy, Debug)]
pub struct Margins {
    /// The top-1 confidence floor (`> 1/2`).
    pub p_star: f64,
}

impl Margins {
    /// `p*` must exceed 1/2 (at exactly 1/2 no arithmetic can help —
    /// paper §IV).
    pub fn new(p_star: f64) -> anyhow::Result<Margins> {
        if !(p_star > 0.5 && p_star <= 1.0) {
            anyhow::bail!("p* must be in (1/2, 1], got {p_star}");
        }
        Ok(Margins { p_star })
    }

    /// Absolute error margin `μ = p* - 1/2` per output element.
    pub fn abs_margin(&self) -> f64 {
        self.p_star - 0.5
    }

    /// Relative error margin `ν = (2p* - 1)/(2p* + 1)`.
    pub fn rel_margin(&self) -> f64 {
        (2.0 * self.p_star - 1.0) / (2.0 * self.p_star + 1.0)
    }
}

/// Smallest precision `k` such that `bound · 2^(1-k) <= margin`.
/// `None` if the bound is infinite or the required k exceeds 53.
fn k_for(bound_in_u: f64, margin: f64) -> Option<u32> {
    debug_assert!(margin > 0.0);
    if !bound_in_u.is_finite() {
        return None;
    }
    if bound_in_u == 0.0 {
        return Some(2);
    }
    // 2^(1-k) <= margin/bound  =>  k >= 1 + log2(bound/margin)
    let k = (1.0 + (bound_in_u / margin).log2()).ceil().max(2.0);
    if k > 53.0 {
        None
    } else {
        Some(k as u32)
    }
}

/// Minimum precision `k` that provably prevents misclassification, given
/// the analysis output bounds (in units of u) and the margins. Either the
/// absolute or the relative condition suffices (whichever allows the
/// smaller k); the result is floored at `k_validity`, the smallest k the
/// analysis covers (`u = 2^(1-k) <= u_max`).
pub fn required_precision(
    max_abs_u: f64,
    max_rel_u: f64,
    margins: Margins,
    u_max: f64,
) -> Option<u32> {
    let k_validity = validity_floor(u_max);
    let k_abs = k_for(max_abs_u, margins.abs_margin());
    let k_rel = k_for(max_rel_u, margins.rel_margin());
    let k = match (k_abs, k_rel) {
        (Some(a), Some(r)) => a.min(r),
        (Some(a), None) => a,
        (None, Some(r)) => r,
        (None, None) => return None,
    };
    Some(k.max(k_validity))
}

/// Smallest k with `2^(1-k) <= u_max`.
pub fn validity_floor(u_max: f64) -> u32 {
    let mut k = 2u32;
    while 2f64.powi(1 - (k as i32)) > u_max {
        k += 1;
        if k > 64 {
            break;
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worked_example() {
        // §IV: p* = 0.60 => ν = 1/11 = 0.0909..., i.e. "about 3.45 valid
        // bits suffice" (log2(1/ν) = 3.459; the paper rounds to 3.45).
        let m = Margins::new(0.60).unwrap();
        assert!((m.rel_margin() - 1.0 / 11.0).abs() < 1e-15);
        assert!(m.rel_margin() > 2f64.powf(-3.46));
        assert!(m.rel_margin() < 2f64.powf(-3.45));
        assert!((m.abs_margin() - 0.1).abs() < 1e-15);
        // And the absolute-margin side of the worked example:
        // 0.0909/5.5 > 1.65e-2, about 2^-6 fixed-point quantization.
        let abs_in = m.rel_margin() / 5.5;
        assert!(abs_in > 1.65e-2);
        assert!(abs_in < 2f64.powi(-5));
    }

    #[test]
    fn digits_row_reproduces_k8() {
        // Table I Digits: 1.1u abs, 3.4u rel, u_max = 2^-7 => k = 8
        // (margin alone would allow k < 8; the u_max validity floor binds,
        // exactly as in the paper).
        let m = Margins::new(0.60).unwrap();
        let k = required_precision(1.1, 3.4, m, 2f64.powi(-7)).unwrap();
        assert_eq!(k, 8);
    }

    #[test]
    fn mobilenet_row_reproduces_k8() {
        // Table I MobileNet: 22.4u abs, 11.5u rel => still k = 8.
        let m = Margins::new(0.60).unwrap();
        let k = required_precision(22.4, 11.5, m, 2f64.powi(-7)).unwrap();
        assert_eq!(k, 8);
    }

    #[test]
    fn margin_binds_for_loose_bounds() {
        // Huge bounds push k above the validity floor.
        let m = Margins::new(0.60).unwrap();
        let k = required_precision(1e4, 1e4, m, 2f64.powi(-7)).unwrap();
        // abs: 1e4 * 2^(1-k) <= 0.1 => k >= 1 + log2(1e5) = 17.6 => 18.
        assert_eq!(k, 18);
    }

    #[test]
    fn one_sided_bounds() {
        let m = Margins::new(0.75).unwrap();
        // Only an absolute bound (the Pendulum case).
        let k = required_precision(1.7, f64::INFINITY, m, 2f64.powi(-7)).unwrap();
        assert_eq!(k, 8);
        // No bound at all.
        assert_eq!(required_precision(f64::INFINITY, f64::INFINITY, m, 0.01), None);
    }

    #[test]
    fn validity_floor_values() {
        assert_eq!(validity_floor(2f64.powi(-7)), 8);
        assert_eq!(validity_floor(2f64.powi(-11)), 12);
        assert_eq!(validity_floor(0.25), 3);
    }

    #[test]
    fn rejects_bad_p_star() {
        assert!(Margins::new(0.5).is_err());
        assert!(Margins::new(0.0).is_err());
        assert!(Margins::new(1.5).is_err());
        assert!(Margins::new(0.51).is_ok());
    }

    #[test]
    fn k_monotone_in_bounds() {
        let m = Margins::new(0.6).unwrap();
        let mut last = 0;
        for b in [0.5, 2.0, 8.0, 32.0, 1e3, 1e6] {
            let k = required_precision(b, b, m, 2f64.powi(-7)).unwrap();
            assert!(k >= last, "k must grow with looser bounds");
            last = k;
        }
    }
}
