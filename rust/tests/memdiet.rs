//! Plan memory-diet regression tests.
//!
//! The diet has three legs, each pinned here:
//!
//! 1. **Arc-shared weights** — a compiled step shares the model layer's
//!    weight tensor unless fusion had to rewrite it (batch-norm
//!    folding), in which case the plan owns a private copy and the
//!    layer's parameters stay untouched.
//! 2. **No third dense copy** — a blocked plan whose dense weights were
//!    folded keeps only the packed panel ([`DenseWeights::PanelOnly`]);
//!    the scalar escape hatch reconstructs the row-major view via
//!    `DensePanel::unpack`, bit-exactly.
//! 3. **Per-row-class im2col** — conv patch tables are `O(ow * k)` per
//!    row class instead of `O(oh * ow * k)`, with interior rows sharing
//!    one class through a vertical delta; results stay bit-identical.
//!
//! A byte-counting allocator verifies the diet at the system boundary:
//! compiling the cached blocked `residual_cnn` plan must allocate well
//! under the pre-diet footprint that [`Plan::memory_report`] reports as
//! `baseline`.

use rigor::layers::gemm::DensePanel;
use rigor::layers::Layer;
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, DenseWeights, Fusion, KernelPath, Plan, StepKind};
use rigor::tensor::Tensor;
use rigor::util::Rng;

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- byte-counting allocator ----------------------------------------------
// Net live bytes per thread (tests run on distinct threads, and plan
// compilation is single-threaded, so a thread-local balance is exact).

thread_local! {
    static LIVE_BYTES: Cell<i64> = const { Cell::new(0) };
}

fn credit(delta: i64) {
    let _ = LIVE_BYTES.try_with(|c| c.set(c.get() + delta));
}

fn live_bytes() -> i64 {
    LIVE_BYTES.try_with(|c| c.get()).unwrap_or(0)
}

struct ByteCountingAlloc;

// SAFETY: delegates every operation to `System`; the balance hook has no
// effect on allocation behavior.
unsafe impl GlobalAlloc for ByteCountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        credit(layout.size() as i64);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        credit(layout.size() as i64);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        credit(new_size as i64 - layout.size() as i64);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        credit(-(layout.size() as i64));
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: ByteCountingAlloc = ByteCountingAlloc;

// ---- helpers --------------------------------------------------------------

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} ({x} vs {y})");
    }
}

fn batch_input(model: &Model, batch: usize, seed: u64) -> Vec<f64> {
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..batch * n).map(|_| rng.range(-1.0, 1.0)).collect()
}

/// A dense layer followed by batch norm: folding rewrites the dense
/// weights, so the blocked plan's only copy is the packed panel.
fn panel_only_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    Model {
        name: "panel_only".into(),
        input_shape: vec![6],
        layers: vec![
            zoo::dense(&mut rng, 6, 5),
            zoo::batch_norm(&mut rng, 5),
            Layer::Relu,
            zoo::dense(&mut rng, 5, 3),
            Layer::Softmax,
        ],
        graph: None,
    }
}

// ---- the headline acceptance number ---------------------------------------

/// The cached blocked `residual_cnn` plan (Full fusion — the serving
/// configuration) must resident-cost less than half its pre-diet
/// baseline, with every field of the report pinned exactly so any
/// accounting drift is loud.
#[test]
fn residual_cnn_resident_bytes_halved() {
    let plan =
        Plan::build_with_kernels(&zoo::residual_cnn(7), Fusion::Full, KernelPath::Blocked).unwrap();
    let report = plan.memory_report();
    assert_eq!(report.weight_bytes(), 424, "plan-owned parameter bytes");
    assert_eq!(report.shared_bytes(), 3232, "layer-shared parameter bytes");
    assert_eq!(report.panel_bytes(), 2304, "packed dense panels");
    assert_eq!(report.table_bytes(), 12240, "conv/pool gather tables");
    assert_eq!(report.resident_bytes(), 14968, "total resident");
    assert_eq!(report.baseline_bytes(), 30440, "pre-diet baseline");
    assert!(
        report.baseline_bytes() >= 2 * report.resident_bytes(),
        "diet must at least halve residency: baseline {} vs resident {}",
        report.baseline_bytes(),
        report.resident_bytes()
    );
}

/// Same exact pinning for `avgpool_cnn` — the model that exercises all
/// three row-class table kinds at once (standard conv im2col, depthwise
/// tap table, and the single-class average-pool table). The 6x6 same-pad
/// 3x3 geometries factor into 3 row classes (top edge, shared interior,
/// bottom edge) of `ow * k` entries each, and the pool degenerates to one
/// class; the per-step table bytes pin that factoring.
#[test]
fn avgpool_cnn_memory_report_pinned() {
    let plan =
        Plan::build_with_kernels(&zoo::avgpool_cnn(7), Fusion::Full, KernelPath::Blocked).unwrap();
    let report = plan.memory_report();
    // 3 classes x (6*9 entries x 8 B) + 6-row map x 16 B for both the
    // conv and the depthwise table; 1 class x (3*4 x 8 B) + 3 x 16 B for
    // the pool.
    let conv = report.steps.iter().find(|s| s.kind == "conv2d").expect("conv step");
    assert_eq!(conv.table_bytes, 1392, "conv im2col row-class table");
    let dw = report.steps.iter().find(|s| s.kind == "depthwise_conv2d").expect("depthwise step");
    assert_eq!(dw.table_bytes, 1392, "depthwise row-class tap table");
    let pool = report.steps.iter().find(|s| s.kind == "avg_pool2d").expect("pool step");
    assert_eq!(pool.table_bytes, 144, "single-class pool table");
    assert_eq!(report.table_bytes(), 2928, "total gather tables");
    assert_eq!(report.resident_bytes(), 5624, "total resident");
    assert_eq!(report.baseline_bytes(), 9896, "pre-diet baseline");
}

// ---- leg 1: every weight stored once --------------------------------------

#[test]
fn weights_shared_with_layers_unless_folded() {
    let model = zoo::residual_cnn(7);
    let pristine = zoo::residual_cnn(7); // same seed: bitwise-equal params
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let mut folded = 0;
    for (i, step) in plan.steps().iter().enumerate() {
        let layer = &model.layers[step.layer_range.0];
        match (&step.kind, layer) {
            (StepKind::Conv2D { kernel, .. }, Layer::Conv2D { kernel: lk, .. }) => {
                if kernel.folded() {
                    folded += 1;
                    assert!(!kernel.shares(lk), "s{i}: folded kernel must be a private copy");
                    // Folding never mutates the model's own parameters.
                    let fresh = match &pristine.layers[step.layer_range.0] {
                        Layer::Conv2D { kernel, .. } => kernel,
                        _ => unreachable!(),
                    };
                    assert_eq!(lk.data(), fresh.data(), "s{i}: layer params mutated by fold");
                } else {
                    assert!(kernel.shares(lk), "s{i}: unfolded conv kernel must share storage");
                }
            }
            (
                StepKind::DepthwiseConv2D { kernel, .. },
                Layer::DepthwiseConv2D { kernel: lk, .. },
            ) => {
                assert!(kernel.shares(lk), "s{i}: depthwise kernel must share storage");
            }
            (StepKind::Dense { w, .. }, Layer::Dense { w: lw, .. }) => match w {
                DenseWeights::Tensor(sw) => {
                    assert!(sw.shares(lw), "s{i}: unfolded dense weights must share storage")
                }
                DenseWeights::PanelOnly { .. } => {
                    panic!("s{i}: residual_cnn has no folded dense step")
                }
            },
            _ => {}
        }
    }
    // Exactly one fold site: the batch norm behind the stem conv.
    assert_eq!(folded, 1, "residual_cnn folds exactly one conv");
}

// ---- leg 2: panel-only dense weights --------------------------------------

#[test]
fn folded_blocked_dense_keeps_only_the_panel() {
    let model = panel_only_model(11);
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let step = &plan.steps()[0];
    let w = match &step.kind {
        StepKind::Dense { w, .. } => w,
        k => panic!("expected a dense stem, got {k:?}"),
    };
    assert!(
        matches!(w, DenseWeights::PanelOnly { .. }),
        "folded dense weights of a blocked plan must drop the row-major tensor"
    );
    assert_eq!(w.dims(), (5, 6), "panel-only form keeps the dims");
    assert!(plan.to_text().contains("wsrc=panel"), "IR must report the panel-only source");
    // The scalar escape hatch unpacks the panel on demand: both paths of
    // the same (blocked) plan must agree bit-for-bit.
    for batch in [1usize, 7, 32] {
        let input = batch_input(&model, batch, 0xD1E7 + batch as u64);
        let mut sa: Arena<f64> = Arena::new();
        let scalar = plan
            .execute_batch_path::<f64>(&(), &input, batch, &mut sa, KernelPath::Scalar)
            .unwrap()
            .to_vec();
        let mut ba: Arena<f64> = Arena::new();
        let blocked = plan
            .execute_batch_path::<f64>(&(), &input, batch, &mut ba, KernelPath::Blocked)
            .unwrap()
            .to_vec();
        assert_bits_eq(&scalar, &blocked, &format!("panel_only B={batch}"));
    }
}

#[test]
fn panel_pack_unpack_is_exact_for_ragged_shapes() {
    // Odd row counts exercise the zero-filled tail rows of the last tile.
    let mut rng = Rng::new(3);
    for (m, n) in [(1, 1), (1, 7), (3, 4), (4, 4), (5, 6), (8, 3), (13, 17)] {
        let w = Tensor::new(vec![m, n], (0..m * n).map(|_| rng.normal()).collect());
        let back = DensePanel::pack(&w).unpack();
        assert_eq!(back.shape(), w.shape(), "{m}x{n}: shape");
        assert_bits_eq(back.data(), w.data(), &format!("{m}x{n}: unpack"));
    }
}

// ---- leg 3: per-row-class im2col ------------------------------------------

/// Conv geometries that stress the row-class machinery: same-padding
/// (edge classes above and below), valid padding (every row interior),
/// and strides that desynchronize rows from the padding pattern. The
/// scalar kernel never consults the table, so bit-identity across paths
/// proves the class tables resolve every tap the full table would.
#[test]
fn per_row_im2col_matches_scalar_kernels_bitwise() {
    use rigor::layers::Padding;
    let mut cases: Vec<Model> = vec![zoo::tiny_cnn(5), zoo::avgpool_cnn(6), zoo::residual_cnn(8)];
    let mut rng = Rng::new(21);
    for (h, w, kh, kw, stride, padding) in [
        (7, 5, 3, 3, 2, Padding::Same),
        (8, 8, 3, 3, 1, Padding::Valid),
        (9, 6, 5, 3, 2, Padding::Valid),
        (6, 6, 1, 1, 1, Padding::Same),
    ] {
        // Output extent per axis, mirroring the layer shape rules.
        let out = |n: usize, k: usize| match padding {
            Padding::Same => n.div_ceil(stride),
            Padding::Valid => (n - k) / stride + 1,
        };
        let flat = out(h, kh) * out(w, kw) * 3;
        cases.push(Model {
            name: format!("conv_{h}x{w}_k{kh}x{kw}_s{stride}"),
            input_shape: vec![h, w, 2],
            layers: vec![
                zoo::conv2d(&mut rng, kh, kw, 2, 3, stride, padding),
                Layer::Relu,
                Layer::Flatten,
                zoo::dense(&mut rng, flat, 4),
                Layer::Softmax,
            ],
            graph: None,
        });
    }
    for model in &cases {
        let plan = Plan::build_with_kernels(model, Fusion::Full, KernelPath::Blocked).unwrap();
        for batch in [1usize, 7, 32] {
            let input = batch_input(model, batch, 0xC0 + batch as u64);
            let mut sa: Arena<f64> = Arena::new();
            let scalar = plan
                .execute_batch_path::<f64>(&(), &input, batch, &mut sa, KernelPath::Scalar)
                .unwrap()
                .to_vec();
            let mut ba: Arena<f64> = Arena::new();
            let blocked = plan
                .execute_batch_path::<f64>(&(), &input, batch, &mut ba, KernelPath::Blocked)
                .unwrap()
                .to_vec();
            assert_bits_eq(&scalar, &blocked, &format!("{} B={batch}", model.name));
        }
    }
}

// ---- the system boundary: real allocations --------------------------------

/// Compiling the cached blocked `residual_cnn` plan allocates its
/// resident payload (~15 KB) plus small bookkeeping — and stays far
/// under the 30,440-byte pre-diet payload floor. A regression that
/// re-materializes per-weight copies, a third dense tensor, or full
/// per-pixel patch tables lands above the bound.
#[test]
fn plan_compilation_allocates_under_the_pre_diet_floor() {
    let model = zoo::residual_cnn(7);
    // Warm up once: lazy runtime/TLS allocations settle before measuring.
    let warm = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let resident = warm.memory_report().resident_bytes() as i64;
    let baseline = warm.memory_report().baseline_bytes() as i64;
    drop(warm);

    let before = live_bytes();
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let delta = live_bytes() - before;
    assert!(
        delta >= resident,
        "compile allocated {delta} B, less than the reported resident {resident} B?"
    );
    assert!(
        delta < baseline - 4096,
        "compile allocated {delta} B — within 4 KB of the pre-diet payload ({baseline} B); \
         did a weight copy or full patch table come back?"
    );
    drop(plan);
}
