//! Integration tests for the compiled execution plan (PR "compile models
//! into a shape-resolved, fused, buffer-reusing execution Plan"):
//!
//! * every `model::zoo` network compiles, and the plan's step-by-step
//!   inferred shapes match the legacy per-layer `output_shape` path;
//! * fused (batch-norm-folded) f64 execution matches unfused within a
//!   1-ulp-scale tolerance;
//! * **soundness regression**: CAA error bounds from the plan executor are
//!   bit-identical to the pre-refactor per-layer interpreter on the digits
//!   workload — fusion must never silently tighten (or loosen) bounds;
//! * the `Session` front door produces the same outcome as the interpreter
//!   oracle, serial and pooled;
//! * **graph topologies** (PR "graph-topology Plan IR"): sequential models
//!   still compile to exactly two pool buffers, residual plans match
//!   hand-written walks bitwise, CAA bounds enclose sampled runs across
//!   merge points, malformed graph JSON is rejected descriptively, and
//!   both residual zoo models run/certify/tune through `Session`.

#![allow(deprecated)] // Model::forward_interpreted is the equivalence oracle

use rigor::analysis::{analyze_class, AnalysisConfig};
use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::caa::{Caa, Ctx};
use rigor::data::{synthetic, Dataset};
use rigor::interval::Interval;
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, Fusion, Plan};
use rigor::tensor::Tensor;
use rigor::util::Rng;

fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::tiny_pendulum(3),
        zoo::scaled_mlp(4, 32, 48, 10),
    ]
}

fn digits_setup() -> (Model, Dataset) {
    let mut rng = Rng::new(3);
    let data = synthetic::digits(&mut rng, 8, 2, 0.05);
    let model = zoo::scaled_mlp(1, 64, 32, 10);
    (model, data)
}

#[test]
fn every_zoo_network_compiles_with_legacy_shapes() {
    for model in zoo_models() {
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            let plan = Plan::build(&model, fusion).unwrap();
            // The plan's chained shapes must traverse exactly the legacy
            // per-layer output_shape sequence (fusion may skip
            // intermediates but never disagree with them).
            let mut legacy = vec![model.input_shape.clone()];
            let mut s = model.input_shape.clone();
            for layer in &model.layers {
                s = layer.output_shape(&s).unwrap();
                legacy.push(s.clone());
            }
            for step in plan.steps() {
                assert_eq!(
                    step.in_shape(),
                    legacy[step.layer_range.0].as_slice(),
                    "{}/{fusion:?}: step input shape",
                    model.name
                );
                assert_eq!(
                    step.out_shape, legacy[step.layer_range.1],
                    "{}/{fusion:?}: step output shape",
                    model.name
                );
            }
            assert_eq!(plan.output_shape(), legacy.last().unwrap().as_slice());
        }
    }
}

#[test]
fn fused_f64_matches_unfused_within_ulp_scale() {
    for model in [zoo::tiny_cnn(7), zoo::tiny_cnn(19)] {
        let n: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(41);
        let unfused = Plan::unfused(&model).unwrap();
        let fused = Plan::for_reference(&model).unwrap();
        let mut a1: Arena<f64> = Arena::new();
        let mut a2: Arena<f64> = Arena::new();
        for _ in 0..4 {
            let x: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
            let y1 = unfused.execute::<f64>(&(), &x, &mut a1).unwrap().to_vec();
            let y2 = fused.execute::<f64>(&(), &x, &mut a2).unwrap();
            for (u, f) in y1.iter().zip(y2) {
                let scale = u.abs().max(1.0);
                assert!(
                    (u - f).abs() <= 1e-10 * scale,
                    "{}: fused {f:e} deviates from unfused {u:e}",
                    model.name
                );
            }
        }
    }
}

/// The pre-refactor interpreter's per-class analysis, reproduced verbatim
/// as the regression oracle.
fn analyze_class_interpreted(
    model: &Model,
    cfg: &AnalysisConfig,
    sample: &[f64],
) -> Vec<Caa> {
    let data: Vec<Caa> = sample
        .iter()
        .map(|&v| {
            let range = if cfg.input_radius > 0.0 {
                Interval::new(v - cfg.input_radius, v + cfg.input_radius)
            } else {
                Interval::point(v)
            };
            if cfg.exact_inputs {
                Caa::input_exact(range, v)
            } else {
                Caa::input(&cfg.ctx, range, v)
            }
        })
        .collect();
    let input = Tensor::new(model.input_shape.clone(), data);
    model
        .forward_interpreted::<Caa>(&cfg.ctx, input)
        .unwrap()
        .into_data()
}

#[test]
fn caa_bounds_bit_identical_to_interpreter_on_digits() {
    // Soundness regression for the tentpole: the plan executor (with the
    // analysis fusion level) must reproduce the interpreter's CAA bounds
    // *bit for bit* on the digits workload — for point inputs, boxed
    // inputs, and exact-input mode.
    let (model, data) = digits_setup();
    let configs = [
        AnalysisConfig::default(),
        AnalysisConfig { exact_inputs: true, ..AnalysisConfig::default() },
        AnalysisConfig { input_radius: 0.05, ..AnalysisConfig::default() },
        AnalysisConfig { ctx: Ctx::with_u_max(2f64.powi(-15)), ..AnalysisConfig::default() },
    ];
    for cfg in &configs {
        for (class, idx) in data.class_representatives() {
            let sample = &data.inputs[idx];
            let oracle = analyze_class_interpreted(&model, cfg, sample);
            let got = analyze_class(&model, cfg, class, sample).unwrap();

            let oracle_abs = oracle.iter().map(|o| o.abs_bound()).fold(0.0f64, f64::max);
            let oracle_rel = oracle.iter().map(|o| o.rel_bound()).fold(0.0f64, f64::max);
            assert_eq!(
                got.max_abs_u.to_bits(),
                oracle_abs.to_bits(),
                "class {class}: abs bound drifted from the interpreter"
            );
            assert_eq!(
                got.max_rel_u.to_bits(),
                oracle_rel.to_bits(),
                "class {class}: rel bound drifted from the interpreter"
            );
        }
    }
}

#[test]
fn session_outcome_identical_to_interpreter_oracle() {
    let (model, data) = digits_setup();
    let cfg = AnalysisConfig::default();

    // Oracle: worst-case bounds over all representatives, via the
    // deprecated interpreter walk.
    let mut oracle_abs = 0.0f64;
    let mut oracle_rel = 0.0f64;
    for (_, idx) in data.class_representatives() {
        let outs = analyze_class_interpreted(&model, &cfg, &data.inputs[idx]);
        oracle_abs = outs.iter().map(|o| o.abs_bound()).fold(oracle_abs, f64::max);
        oracle_rel = outs.iter().map(|o| o.rel_bound()).fold(oracle_rel, f64::max);
    }

    let session = Session::builder().workers(4).build();
    for mode in [ExecMode::Serial, ExecMode::Pooled { workers: 0 }] {
        let req = AnalysisRequest::builder()
            .model(model.clone())
            .data(data.clone())
            .mode(mode)
            .build()
            .unwrap();
        let out = session.run(&req).unwrap();
        assert_eq!(out.analysis.max_abs_u.to_bits(), oracle_abs.to_bits(), "{mode:?}");
        assert_eq!(out.analysis.max_rel_u.to_bits(), oracle_rel.to_bits(), "{mode:?}");
    }
}

// ---------------------------------------------------------------------------
// Graph-topology plans (PR "graph-topology Plan IR"): buffer-pool
// regression, hand-walked residual equivalence, merge-point soundness,
// malformed-graph rejection, and the Session front door on branchy models.
// ---------------------------------------------------------------------------

#[test]
fn sequential_models_still_compile_to_two_pool_buffers() {
    // No-regression guarantee of the pool allocator: straight-line models
    // keep the exact two-buffer ping-pong (and with it, the steady-state
    // allocation profile) at every fusion level.
    for model in zoo_models() {
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            let plan = Plan::build(&model, fusion).unwrap();
            assert_eq!(plan.buffer_count(), 2, "{} at {fusion:?}", model.name);
        }
    }
}

#[test]
fn residual_mlp_matches_hand_written_walk_bitwise() {
    // Oracle for the graph executor: evaluate residual_mlp by hand with
    // the per-layer interpreter pieces plus explicit merge arithmetic,
    // and require bit-identical f64 outputs from the compiled plan (both
    // unfused and paired — pairing must not change the arithmetic).
    let m = zoo::residual_mlp(33);
    let mut rng = Rng::new(4);
    let x: Vec<f64> = (0..8).map(|_| rng.range(0.0, 1.0)).collect();

    let t = |v: Vec<f64>| Tensor::new(vec![8], v);
    let d1 = m.layers[0].apply(&(), &t(x.clone())).unwrap();
    let a1 = m.layers[1].apply(&(), &d1).unwrap();
    let d2 = m.layers[2].apply(&(), &a1).unwrap();
    // add1 = d2 + a1 (left-to-right in declared inbound order), then ReLU.
    let sum: Vec<f64> = d2.data().iter().zip(a1.data()).map(|(p, q)| p + q).collect();
    let a2: Vec<f64> = sum.iter().map(|v| v.max(0.0)).collect();
    let d3 = m.layers[5].apply(&(), &t(a2)).unwrap();
    let oracle = m.layers[6].apply(&(), &d3).unwrap();

    for fusion in [Fusion::None, Fusion::Pair] {
        let plan = Plan::build(&m, fusion).unwrap();
        let mut arena = Arena::new();
        let got = plan.execute::<f64>(&(), &x, &mut arena).unwrap();
        assert_eq!(got, oracle.data(), "{fusion:?} must match the hand walk bitwise");
    }
}

#[test]
fn residual_cnn_matches_hand_written_walk_bitwise() {
    let m = zoo::residual_cnn(34);
    let mut rng = Rng::new(5);
    let x: Vec<f64> = (0..36).map(|_| rng.range(0.0, 1.0)).collect();

    let c1 = m.layers[0].apply(&(), &Tensor::new(vec![6, 6, 1], x.clone())).unwrap();
    let b1 = m.layers[1].apply(&(), &c1).unwrap();
    let r1 = m.layers[2].apply(&(), &b1).unwrap();
    let c2 = m.layers[3].apply(&(), &r1).unwrap();
    let sum: Vec<f64> = c2.data().iter().zip(r1.data()).map(|(p, q)| p + q).collect();
    let r2 = Tensor::new(vec![6, 6, 4], sum.iter().map(|v| v.max(0.0)).collect::<Vec<f64>>());
    let c3 = m.layers[6].apply(&(), &r2).unwrap();
    let c4 = m.layers[7].apply(&(), &r2).unwrap();
    // concat along channels: per spatial position, c3's 2 channels then
    // c4's 2 channels.
    let mut cat = Vec::with_capacity(36 * 4);
    for p in 0..36 {
        cat.extend_from_slice(&c3.data()[p * 2..(p + 1) * 2]);
        cat.extend_from_slice(&c4.data()[p * 2..(p + 1) * 2]);
    }
    let r3 = Tensor::new(vec![6, 6, 4], cat.iter().map(|v| v.max(0.0)).collect::<Vec<f64>>());
    let p1 = m.layers[10].apply(&(), &r3).unwrap();
    let f1 = m.layers[11].apply(&(), &p1).unwrap();
    let d1 = m.layers[12].apply(&(), &f1).unwrap();
    let oracle = m.layers[13].apply(&(), &d1).unwrap();

    for fusion in [Fusion::None, Fusion::Pair] {
        let plan = Plan::build(&m, fusion).unwrap();
        let mut arena = Arena::new();
        let got = plan.execute::<f64>(&(), &x, &mut arena).unwrap();
        assert_eq!(got, oracle.data(), "{fusion:?} must match the hand walk bitwise");
    }
}

#[test]
fn merge_bounds_enclose_sampled_emulated_runs() {
    // Soundness across merge points: the CAA interval enclosure contains
    // every sampled precision-k execution, and the absolute/relative
    // error bounds dominate the observed deviation from the f64 trace.
    for model in [zoo::residual_mlp(51), zoo::residual_cnn(52)] {
        let plan = Plan::for_analysis(&model).unwrap();
        let n: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(77);
        for sample in 0..3 {
            let x: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
            let mut arena = Arena::new();
            let yr = plan.execute::<f64>(&(), &x, &mut arena).unwrap().to_vec();

            let ctx = Ctx::new();
            let xc: Vec<Caa> =
                x.iter().map(|&v| Caa::input(&ctx, Interval::point(v), v)).collect();
            let mut caa_arena = Arena::new();
            let yc = plan.execute::<Caa>(&ctx, &xc, &mut caa_arena).unwrap().to_vec();

            for k in [8u32, 12, 16] {
                let emu = rigor::quant::emulated_forward(&plan, k, &x).unwrap();
                for i in 0..yr.len() {
                    assert!(
                        yc[i].rounded().inflate(1e-9).contains(emu[i]),
                        "{} sample {sample} k={k} output {i}: emulated value \
                         outside the rounded enclosure",
                        model.name
                    );
                    rigor::quant::check_against_bounds(&yc[i], yr[i], emu[i], k, 1e-12)
                        .unwrap_or_else(|e| {
                            panic!("{} sample {sample} k={k} output {i}: {e}", model.name)
                        });
                }
            }
        }
    }
}

#[test]
fn malformed_graph_json_reports_descriptive_errors() {
    use rigor::model::model_from_json;
    // A cycle (d1 -> d2 -> d1) with an explicit output node.
    let cycle = r#"{
        "name": "m", "input_shape": [2], "output": "s",
        "layers": [
            {"type": "dense", "units": 2, "in": 2,
             "weights": [1, 0, 0, 1], "bias": [0, 0],
             "name": "d1", "inbound": ["d2"]},
            {"type": "dense", "units": 2, "in": 2,
             "weights": [1, 0, 0, 1], "bias": [0, 0],
             "name": "d2", "inbound": ["d1"]},
            {"type": "add", "name": "s", "inbound": ["d1", "d2"]}
        ]
    }"#;
    let err = model_from_json(&rigor::json::parse(cycle).unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("cycle"), "{err:#}");

    // A dangling edge: inbound references a node that does not exist.
    let dangling = r#"{
        "name": "m", "input_shape": [2],
        "layers": [
            {"type": "dense", "units": 2, "in": 2,
             "weights": [1, 0, 0, 1], "bias": [0, 0],
             "name": "d1", "inbound": ["missing_node"]}
        ]
    }"#;
    let err = model_from_json(&rigor::json::parse(dangling).unwrap()).unwrap_err();
    let chain = format!("{err:#}");
    assert!(
        chain.contains("missing_node") && chain.contains("dangling"),
        "{chain}"
    );
}

#[test]
fn residual_models_run_certify_and_tune_through_session() {
    // The acceptance path: both residual zoo models flow through the
    // Session front door end to end — run (serial + pooled), the §V
    // certify loop, and §VI greedy mixed tuning — with finite bounds.
    let session = Session::builder().workers(2).build();
    for model in [zoo::residual_mlp(42), zoo::residual_cnn(43)] {
        let n: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(9);
        let inputs: Vec<Vec<f64>> =
            (0..3).map(|_| (0..n).map(|_| rng.range(0.0, 1.0)).collect()).collect();
        let data = Dataset {
            input_shape: model.input_shape.clone(),
            inputs,
            labels: vec![0, 1, 2],
        };

        for mode in [ExecMode::Serial, ExecMode::Pooled { workers: 0 }] {
            let req = AnalysisRequest::builder()
                .model(model.clone())
                .data(data.clone())
                .mode(mode)
                .build()
                .unwrap();
            let out = session.run(&req).unwrap();
            assert_eq!(out.analysis.per_class.len(), 3, "{}", model.name);
            assert!(
                out.analysis.max_abs_u.is_finite() && out.analysis.max_abs_u > 0.0,
                "{} ({mode:?}): finite positive CAA bound",
                model.name
            );
        }

        let req = AnalysisRequest::builder()
            .model(model.clone())
            .data(data.clone())
            .p_star(0.60)
            .build()
            .unwrap();
        let (k, outcome) = session
            .certify_min_precision(&req, 4..=44)
            .unwrap()
            .unwrap_or_else(|| panic!("{} must certify in [4, 44]", model.name));
        assert!(outcome.required_k().unwrap() <= k, "{}", model.name);
        assert!(outcome.analysis.max_abs_u.is_finite());

        let k_uniform = (k + 4).min(53);
        let tuned = session.tune_mixed(&req, k_uniform, 4).unwrap();
        assert!(tuned.certified, "{}: tuned assignment stays certified", model.name);
        assert!(tuned.max_abs.is_finite());
        assert_eq!(tuned.ks.len(), model.layers.len());
        assert!(tuned.ks.iter().all(|&kk| kk <= k_uniform), "{}", model.name);

        // Baselines run on graph models through the same compiled plan.
        let cfg = req.analysis_config();
        let ia = rigor::analysis::baseline::ia_only_class(&model, &cfg, 0, &data.inputs[0])
            .unwrap();
        assert!(ia.max_abs_u > 0.0, "{}: IA-only baseline", model.name);
        let (obs_abs, _) =
            rigor::analysis::baseline::sampling_estimate(&model, 12, &data.inputs).unwrap();
        assert!(obs_abs.is_finite(), "{}: sampling baseline", model.name);
    }
}

#[test]
fn emulated_witness_plan_entry_point() {
    // quant::emulated_forward (the plan-driven witness) matches the
    // model-level emulated execution bitwise.
    use rigor::quant::EmulatedFp;
    use rigor::tensor::EmuCtx;
    let model = zoo::tiny_cnn(9);
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(11);
    let x: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
    let plan = Plan::unfused(&model).unwrap();
    for k in [8u32, 16] {
        let got = rigor::quant::emulated_forward(&plan, k, &x).unwrap();
        let ec = EmuCtx { k };
        let xe = Tensor::new(
            model.input_shape.clone(),
            x.iter().map(|&v| EmulatedFp::new(v, k)).collect::<Vec<_>>(),
        );
        let reference = model.forward_interpreted::<EmulatedFp>(&ec, xe).unwrap();
        for (g, r) in got.iter().zip(reference.data()) {
            assert_eq!(g.to_bits(), r.v.to_bits(), "k={k}");
        }
    }
}
