//! Integration tests for the compiled execution plan (PR "compile models
//! into a shape-resolved, fused, buffer-reusing execution Plan"):
//!
//! * every `model::zoo` network compiles, and the plan's step-by-step
//!   inferred shapes match the legacy per-layer `output_shape` path;
//! * fused (batch-norm-folded) f64 execution matches unfused within a
//!   1-ulp-scale tolerance;
//! * **soundness regression**: CAA error bounds from the plan executor are
//!   bit-identical to the pre-refactor per-layer interpreter on the digits
//!   workload — fusion must never silently tighten (or loosen) bounds;
//! * the `Session` front door produces the same outcome as the interpreter
//!   oracle, serial and pooled.

#![allow(deprecated)] // Model::forward_interpreted is the equivalence oracle

use rigor::analysis::{analyze_class, AnalysisConfig};
use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::caa::{Caa, Ctx};
use rigor::data::{synthetic, Dataset};
use rigor::interval::Interval;
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, Fusion, Plan};
use rigor::tensor::Tensor;
use rigor::util::Rng;

fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::tiny_pendulum(3),
        zoo::scaled_mlp(4, 32, 48, 10),
    ]
}

fn digits_setup() -> (Model, Dataset) {
    let mut rng = Rng::new(3);
    let data = synthetic::digits(&mut rng, 8, 2, 0.05);
    let model = zoo::scaled_mlp(1, 64, 32, 10);
    (model, data)
}

#[test]
fn every_zoo_network_compiles_with_legacy_shapes() {
    for model in zoo_models() {
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            let plan = Plan::build(&model, fusion).unwrap();
            // The plan's chained shapes must traverse exactly the legacy
            // per-layer output_shape sequence (fusion may skip
            // intermediates but never disagree with them).
            let mut legacy = vec![model.input_shape.clone()];
            let mut s = model.input_shape.clone();
            for layer in &model.layers {
                s = layer.output_shape(&s).unwrap();
                legacy.push(s.clone());
            }
            for step in plan.steps() {
                assert_eq!(
                    step.in_shape, legacy[step.layer_range.0],
                    "{}/{fusion:?}: step input shape",
                    model.name
                );
                assert_eq!(
                    step.out_shape, legacy[step.layer_range.1],
                    "{}/{fusion:?}: step output shape",
                    model.name
                );
            }
            assert_eq!(plan.output_shape(), legacy.last().unwrap().as_slice());
        }
    }
}

#[test]
fn fused_f64_matches_unfused_within_ulp_scale() {
    for model in [zoo::tiny_cnn(7), zoo::tiny_cnn(19)] {
        let n: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(41);
        let unfused = Plan::unfused(&model).unwrap();
        let fused = Plan::for_reference(&model).unwrap();
        let mut a1: Arena<f64> = Arena::new();
        let mut a2: Arena<f64> = Arena::new();
        for _ in 0..4 {
            let x: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
            let y1 = unfused.execute::<f64>(&(), &x, &mut a1).unwrap().to_vec();
            let y2 = fused.execute::<f64>(&(), &x, &mut a2).unwrap();
            for (u, f) in y1.iter().zip(y2) {
                let scale = u.abs().max(1.0);
                assert!(
                    (u - f).abs() <= 1e-10 * scale,
                    "{}: fused {f:e} deviates from unfused {u:e}",
                    model.name
                );
            }
        }
    }
}

/// The pre-refactor interpreter's per-class analysis, reproduced verbatim
/// as the regression oracle.
fn analyze_class_interpreted(
    model: &Model,
    cfg: &AnalysisConfig,
    sample: &[f64],
) -> Vec<Caa> {
    let data: Vec<Caa> = sample
        .iter()
        .map(|&v| {
            let range = if cfg.input_radius > 0.0 {
                Interval::new(v - cfg.input_radius, v + cfg.input_radius)
            } else {
                Interval::point(v)
            };
            if cfg.exact_inputs {
                Caa::input_exact(range, v)
            } else {
                Caa::input(&cfg.ctx, range, v)
            }
        })
        .collect();
    let input = Tensor::new(model.input_shape.clone(), data);
    model
        .forward_interpreted::<Caa>(&cfg.ctx, input)
        .unwrap()
        .into_data()
}

#[test]
fn caa_bounds_bit_identical_to_interpreter_on_digits() {
    // Soundness regression for the tentpole: the plan executor (with the
    // analysis fusion level) must reproduce the interpreter's CAA bounds
    // *bit for bit* on the digits workload — for point inputs, boxed
    // inputs, and exact-input mode.
    let (model, data) = digits_setup();
    let configs = [
        AnalysisConfig::default(),
        AnalysisConfig { exact_inputs: true, ..AnalysisConfig::default() },
        AnalysisConfig { input_radius: 0.05, ..AnalysisConfig::default() },
        AnalysisConfig { ctx: Ctx::with_u_max(2f64.powi(-15)), ..AnalysisConfig::default() },
    ];
    for cfg in &configs {
        for (class, idx) in data.class_representatives() {
            let sample = &data.inputs[idx];
            let oracle = analyze_class_interpreted(&model, cfg, sample);
            let got = analyze_class(&model, cfg, class, sample).unwrap();

            let oracle_abs = oracle.iter().map(|o| o.abs_bound()).fold(0.0f64, f64::max);
            let oracle_rel = oracle.iter().map(|o| o.rel_bound()).fold(0.0f64, f64::max);
            assert_eq!(
                got.max_abs_u.to_bits(),
                oracle_abs.to_bits(),
                "class {class}: abs bound drifted from the interpreter"
            );
            assert_eq!(
                got.max_rel_u.to_bits(),
                oracle_rel.to_bits(),
                "class {class}: rel bound drifted from the interpreter"
            );
        }
    }
}

#[test]
fn session_outcome_identical_to_interpreter_oracle() {
    let (model, data) = digits_setup();
    let cfg = AnalysisConfig::default();

    // Oracle: worst-case bounds over all representatives, via the
    // deprecated interpreter walk.
    let mut oracle_abs = 0.0f64;
    let mut oracle_rel = 0.0f64;
    for (_, idx) in data.class_representatives() {
        let outs = analyze_class_interpreted(&model, &cfg, &data.inputs[idx]);
        oracle_abs = outs.iter().map(|o| o.abs_bound()).fold(oracle_abs, f64::max);
        oracle_rel = outs.iter().map(|o| o.rel_bound()).fold(oracle_rel, f64::max);
    }

    let session = Session::builder().workers(4).build();
    for mode in [ExecMode::Serial, ExecMode::Pooled { workers: 0 }] {
        let req = AnalysisRequest::builder()
            .model(model.clone())
            .data(data.clone())
            .mode(mode)
            .build()
            .unwrap();
        let out = session.run(&req).unwrap();
        assert_eq!(out.analysis.max_abs_u.to_bits(), oracle_abs.to_bits(), "{mode:?}");
        assert_eq!(out.analysis.max_rel_u.to_bits(), oracle_rel.to_bits(), "{mode:?}");
    }
}

#[test]
fn emulated_witness_plan_entry_point() {
    // quant::emulated_forward (the plan-driven witness) matches the
    // model-level emulated execution bitwise.
    use rigor::quant::EmulatedFp;
    use rigor::tensor::EmuCtx;
    let model = zoo::tiny_cnn(9);
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(11);
    let x: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
    let plan = Plan::unfused(&model).unwrap();
    for k in [8u32, 16] {
        let got = rigor::quant::emulated_forward(&plan, k, &x).unwrap();
        let ec = EmuCtx { k };
        let xe = Tensor::new(
            model.input_shape.clone(),
            x.iter().map(|&v| EmulatedFp::new(v, k)).collect::<Vec<_>>(),
        );
        let reference = model.forward_interpreted::<EmulatedFp>(&ec, xe).unwrap();
        for (g, r) in got.iter().zip(reference.data()) {
            assert_eq!(g.to_bits(), r.v.to_bits(), "k={k}");
        }
    }
}
