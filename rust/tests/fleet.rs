//! Fleet integration tests — the acceptance criteria of the multi-model
//! serving scheduler: a 2-model x 2-format concurrent load through one
//! `Fleet` must produce per-ticket results bit-identical to independent
//! single-model `MicroBatcher` runs, no queue may starve (flush share
//! within 2x of fair), a hot swap under concurrent submitters must drop
//! or misroute nothing, and admission control must reject typed.

use rigor::coordinator::Pool;
use rigor::fleet::{AdmitError, Fleet, FleetPolicy};
use rigor::model::zoo;
use rigor::plan::{Arena, Plan, ServeFormat};
use rigor::serve::{BatchPolicy, MicroBatcher};
use std::sync::Arc;
use std::time::Duration;

fn sample(n: usize, i: usize) -> Vec<f64> {
    (0..n).map(|j| ((i * n + j) % 13) as f64 / 13.0).collect()
}

#[test]
fn mixed_fleet_matches_independent_microbatchers_bitwise() {
    // Two models, two formats, four concurrent submitter threads — one
    // per (model, format) queue — through ONE fleet. Every ticket must be
    // bit-identical to the same sample served by an independent
    // single-model MicroBatcher in the same format.
    let mlp = zoo::tiny_mlp(101);
    let cnn = zoo::tiny_cnn(102);
    let emu = ServeFormat::Emulated { k: 12 };
    let n_mlp: usize = mlp.input_shape.iter().product();
    let n_cnn: usize = cnn.input_shape.iter().product();
    const REQS: usize = 24;

    let fleet = Arc::new(Fleet::new(
        Arc::new(Pool::new(4, 32)),
        FleetPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            max_queue_pending: 64,
            max_fleet_pending: 256,
            ..FleetPolicy::default()
        },
    ));
    fleet.deploy("mlp", &mlp).unwrap();
    fleet.deploy("cnn", &cnn).unwrap();

    let lanes: [(&'static str, ServeFormat, usize); 4] = [
        ("mlp", ServeFormat::F64, n_mlp),
        ("mlp", emu, n_mlp),
        ("cnn", ServeFormat::F64, n_cnn),
        ("cnn", emu, n_cnn),
    ];
    let handles: Vec<_> = lanes
        .iter()
        .map(|&(id, fmt, n)| {
            let f = Arc::clone(&fleet);
            std::thread::spawn(move || {
                let tickets: Vec<_> = (0..REQS)
                    .map(|i| f.submit_blocking(id, fmt, sample(n, i)).unwrap())
                    .collect();
                tickets.into_iter().map(|t| t.wait().unwrap()).collect::<Vec<_>>()
            })
        })
        .collect();
    let fleet_results: Vec<Vec<Vec<f64>>> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (lane, &(id, fmt, n)) in lanes.iter().enumerate() {
        let model = if id == "mlp" { &mlp } else { &cnn };
        let plan = Arc::new(Plan::for_format(model, fmt).unwrap());
        let kernels = plan.kernel_path();
        let batcher = MicroBatcher::with_format(
            plan,
            Arc::new(Pool::new(2, 16)),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                max_pending: 64,
                ..BatchPolicy::default()
            },
            kernels,
            fmt,
        );
        let tickets: Vec<_> = (0..REQS).map(|i| batcher.submit(sample(n, i)).unwrap()).collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let want = t.wait().unwrap();
            let got = &fleet_results[lane][i];
            assert_eq!(got.len(), want.len(), "{id}/{fmt} ticket {i}: length");
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{id}/{fmt} ticket {i} element {j}: fleet vs independent batcher"
                );
            }
        }
    }

    let snap = fleet.snapshot();
    assert_eq!(snap.queues.len(), 4, "one queue per (model, format) pair");
    assert_eq!(snap.submitted(), 4 * REQS);
    assert_eq!(snap.total_pending, 0);
}

#[test]
fn flush_shares_stay_within_2x_of_fair() {
    // Four equally-loaded queues: round-robin flushing must keep every
    // queue's flush share within 2x of the fair share. A long max_wait
    // keeps the flushes Full-triggered (48 = 6 full batches per queue),
    // so a starved scheduler would show up as a lopsided batch count.
    let mlp_a = zoo::tiny_mlp(121);
    let mlp_b = zoo::tiny_mlp(122);
    let emu = ServeFormat::Emulated { k: 10 };
    const REQS: usize = 48;

    let fleet = Arc::new(Fleet::new(
        Arc::new(Pool::new(2, 16)),
        FleetPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(200),
            max_queue_pending: 16,
            max_fleet_pending: 64,
            ..FleetPolicy::default()
        },
    ));
    fleet.deploy("a", &mlp_a).unwrap();
    fleet.deploy("b", &mlp_b).unwrap();

    let lanes: [(&'static str, ServeFormat); 4] =
        [("a", ServeFormat::F64), ("a", emu), ("b", ServeFormat::F64), ("b", emu)];
    let handles: Vec<_> = lanes
        .iter()
        .map(|&(id, fmt)| {
            let f = Arc::clone(&fleet);
            std::thread::spawn(move || {
                let tickets: Vec<_> = (0..REQS)
                    .map(|i| f.submit_blocking(id, fmt, sample(8, i)).unwrap())
                    .collect();
                for t in tickets {
                    assert_eq!(t.wait().unwrap().len(), 3);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let snap = fleet.snapshot();
    assert_eq!(snap.submitted(), 4 * REQS);
    let fair = snap.batches() as f64 / snap.queues.len() as f64;
    for q in &snap.queues {
        let share = q.metrics.batches as f64;
        assert!(
            share * 2.0 >= fair && share <= fair * 2.0,
            "queue {:?} flushed {share} batches, fair share is {fair:.1}",
            q.key
        );
        assert!(q.metrics.max_batch_observed <= 8);
    }
}

#[test]
fn hot_swap_under_concurrent_load_drops_and_misroutes_nothing() {
    let v1 = zoo::tiny_mlp(111);
    let v2 = zoo::tiny_mlp(112);
    let fleet = Arc::new(Fleet::new(
        Arc::new(Pool::new(2, 16)),
        FleetPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue_pending: 64,
            max_fleet_pending: 256,
            ..FleetPolicy::default()
        },
    ));
    fleet.deploy("m", &v1).unwrap();

    // Background submitters race the swap: each of their tickets must
    // resolve (no drops) to exactly one version's reference trace (no
    // misroutes — a batch never mixes plans).
    let racing: Vec<_> = (0..2)
        .map(|t: usize| {
            let f = Arc::clone(&fleet);
            std::thread::spawn(move || {
                (0..60)
                    .map(|i| {
                        let s = sample(8, t * 60 + i);
                        let out = f
                            .submit_blocking("m", ServeFormat::F64, s.clone())
                            .unwrap()
                            .wait()
                            .unwrap();
                        (s, out)
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();

    // Main thread: a pre-swap batch (pins v1), the swap, a post-swap
    // batch (must route to v2).
    let pre: Vec<_> = (0..20)
        .map(|i| {
            let s = sample(8, 1000 + i);
            (s.clone(), fleet.submit_blocking("m", ServeFormat::F64, s).unwrap())
        })
        .collect();
    assert_eq!(fleet.deploy("m", &v2).unwrap(), 2);
    let post: Vec<_> = (0..20)
        .map(|i| {
            let s = sample(8, 2000 + i);
            (s.clone(), fleet.submit_blocking("m", ServeFormat::F64, s).unwrap())
        })
        .collect();

    let p1 = Plan::for_reference(&v1).unwrap();
    let p2 = Plan::for_reference(&v2).unwrap();
    let mut arena: Arena<f64> = Arena::new();
    let bits = |plan: &Plan, s: &[f64], arena: &mut Arena<f64>| -> Vec<u64> {
        plan.execute::<f64>(&(), s, arena).unwrap().iter().map(|v| v.to_bits()).collect()
    };
    for (i, (s, t)) in pre.into_iter().enumerate() {
        let got: Vec<u64> = t.wait().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, bits(&p1, &s, &mut arena), "pre-swap ticket {i} must drain on v1");
    }
    for (i, (s, t)) in post.into_iter().enumerate() {
        let got: Vec<u64> = t.wait().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, bits(&p2, &s, &mut arena), "post-swap ticket {i} must route to v2");
    }
    for h in racing {
        for (s, out) in h.join().unwrap() {
            let got: Vec<u64> = out.iter().map(|v| v.to_bits()).collect();
            let w1 = bits(&p1, &s, &mut arena);
            let w2 = bits(&p2, &s, &mut arena);
            assert!(
                got == w1 || got == w2,
                "racing ticket matches neither version's reference trace"
            );
        }
    }
    assert_eq!(fleet.snapshot().swaps, 1);
}

#[test]
fn admission_and_shutdown_reject_typed() {
    let fleet = Fleet::new(Arc::new(Pool::new(1, 4)), FleetPolicy::default());
    assert!(matches!(
        fleet.submit("ghost", ServeFormat::F64, vec![0.0; 8]),
        Err(AdmitError::UnknownModel { .. })
    ));
    fleet.deploy("m", &zoo::tiny_mlp(5)).unwrap();
    assert!(matches!(
        fleet.submit("m", ServeFormat::Emulated { k: 1 }, vec![0.0; 8]),
        Err(AdmitError::BadFormat { .. })
    ));
    assert!(matches!(
        fleet.submit("m", ServeFormat::F64, vec![0.0; 3]),
        Err(AdmitError::WrongLen { expected: 8, got: 3, .. })
    ));
    let t = fleet.submit("m", ServeFormat::F64, vec![0.1; 8]).unwrap();
    assert_eq!(t.wait().unwrap().len(), 3);
    // Shutdown refuses new admissions with its own typed error — and the
    // errors are surfaced in the snapshot's rejection counter.
    fleet.shutdown();
    assert!(matches!(
        fleet.submit("m", ServeFormat::F64, vec![0.1; 8]),
        Err(AdmitError::ShuttingDown)
    ));
    assert!(fleet.snapshot().rejected >= 3);
}
