//! Kernel-dispatch integration tests: the blocked (register-tiled,
//! im2col) kernel path must be **bit-identical** to the scalar path for
//! every model in the zoo, for `f64` and `EmulatedFp`, at every batch
//! size — plus the forced-scalar escape hatches and the arena's
//! monotonic-reservation (allocation-free steady state) contract.

use rigor::api::{AnalysisRequest, Session};
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, Fusion, KernelPath, Plan};
use rigor::quant::EmulatedFp;
use rigor::tensor::EmuCtx;
use rigor::util::Rng;

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- allocation counter ---------------------------------------------------
// A counting wrapper around the system allocator, with a per-thread
// counter so concurrently running tests don't pollute each other's
// measurements. `try_with` keeps the hook safe during TLS teardown.

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counter hook has no
// effect on allocation behavior.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// ---- helpers --------------------------------------------------------------

/// The whole zoo, residual models included. `scaled_mlp` gets prime-ish
/// dims so dense tiles see row *and* lane tails; `avgpool_cnn` pins the
/// blocked average-pool summation kernel.
fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::avgpool_cnn(7),
        zoo::tiny_pendulum(3),
        zoo::scaled_mlp(4, 13, 17, 5),
        zoo::residual_mlp(5),
        zoo::residual_cnn(6),
    ]
}

fn batch_input(model: &Model, batch: usize, seed: u64) -> Vec<f64> {
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..batch * n).map(|_| rng.range(-1.0, 1.0)).collect()
}

fn assert_bits_eq(scalar: &[f64], blocked: &[f64], what: &str) {
    assert_eq!(scalar.len(), blocked.len(), "{what}: length");
    for (i, (a, b)) in scalar.iter().zip(blocked).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: element {i} ({a} vs {b})");
    }
}

// ---- bit-identity across the zoo ------------------------------------------

#[test]
fn blocked_path_bit_identical_across_zoo_f64() {
    // Both fusion levels the f64 paths actually run: Full (reference
    // trace) and Pair (the analysis plan's trace, served by the
    // micro-batcher).
    for model in zoo_models() {
        for fusion in [Fusion::Full, Fusion::Pair] {
            let plan = Plan::build_with_kernels(&model, fusion, KernelPath::Blocked).unwrap();
            for batch in [1usize, 7, 32] {
                let flat = batch_input(&model, batch, 0xF0 + batch as u64);
                let mut sa: Arena<f64> = Arena::new();
                let scalar = plan
                    .execute_batch_path::<f64>(&(), &flat, batch, &mut sa, KernelPath::Scalar)
                    .unwrap()
                    .to_vec();
                let mut ba: Arena<f64> = Arena::new();
                let blocked = plan
                    .execute_batch_path::<f64>(&(), &flat, batch, &mut ba, KernelPath::Blocked)
                    .unwrap()
                    .to_vec();
                assert_bits_eq(&scalar, &blocked, &format!("{} B={batch}", model.name));
            }
            // The single-sample entry point dispatches separately.
            let one = batch_input(&model, 1, 0x51);
            let mut sa: Arena<f64> = Arena::new();
            let scalar = plan
                .execute_path::<f64>(&(), &one, &mut sa, KernelPath::Scalar)
                .unwrap()
                .to_vec();
            let mut ba: Arena<f64> = Arena::new();
            let blocked = plan
                .execute_path::<f64>(&(), &one, &mut ba, KernelPath::Blocked)
                .unwrap()
                .to_vec();
            assert_bits_eq(&scalar, &blocked, &format!("{} single", model.name));
        }
    }
}

#[test]
fn blocked_path_bit_identical_across_zoo_emulated() {
    // The witness configuration: unfused plans (the analyzed
    // computation), emulated precision-k arithmetic.
    for model in zoo_models() {
        let plan = Plan::build_with_kernels(&model, Fusion::None, KernelPath::Blocked).unwrap();
        for k in [8u32, 12] {
            let ec = EmuCtx { k };
            for batch in [1usize, 7, 32] {
                let xe: Vec<EmulatedFp> = batch_input(&model, batch, 0xE0 + batch as u64)
                    .iter()
                    .map(|&v| EmulatedFp::new(v, k))
                    .collect();
                let mut sa: Arena<EmulatedFp> = Arena::new();
                let scalar: Vec<f64> = plan
                    .execute_batch_path::<EmulatedFp>(&ec, &xe, batch, &mut sa, KernelPath::Scalar)
                    .unwrap()
                    .iter()
                    .map(|e| e.v)
                    .collect();
                let mut ba: Arena<EmulatedFp> = Arena::new();
                let blocked: Vec<f64> = plan
                    .execute_batch_path::<EmulatedFp>(&ec, &xe, batch, &mut ba, KernelPath::Blocked)
                    .unwrap()
                    .iter()
                    .map(|e| e.v)
                    .collect();
                assert_bits_eq(&scalar, &blocked, &format!("{} k={k} B={batch}", model.name));
            }
        }
    }
}

#[test]
fn odd_and_prime_shapes_hit_every_tile_tail() {
    // Ad-hoc models whose dims divide neither MR (4) nor NR (8): dense
    // 13 -> 29 -> 3, and a conv stack with prime channel counts, odd
    // spatial extent, stride 2 and both paddings.
    use rigor::layers::{Layer, Padding};
    let mut rng = Rng::new(42);
    let dense_net = Model {
        name: "prime_mlp".into(),
        input_shape: vec![13],
        layers: vec![
            zoo::dense(&mut rng, 13, 29),
            Layer::Relu,
            zoo::dense(&mut rng, 29, 3),
            Layer::Softmax,
        ],
        graph: None,
    };
    let conv_net = Model {
        name: "prime_cnn".into(),
        input_shape: vec![7, 5, 3],
        layers: vec![
            zoo::conv2d(&mut rng, 3, 3, 3, 5, 1, Padding::Same),
            Layer::Relu,
            zoo::conv2d(&mut rng, 3, 3, 5, 2, 2, Padding::Valid),
            zoo::depthwise(&mut rng, 2, 2, 2, 1, Padding::Same),
            Layer::Flatten,
            zoo::dense(&mut rng, 3 * 2 * 2, 3),
            Layer::Softmax,
        ],
        graph: None,
    };
    for model in [dense_net, conv_net] {
        let plan = Plan::build_with_kernels(&model, Fusion::Pair, KernelPath::Blocked).unwrap();
        for batch in [1usize, 5, 9] {
            let flat = batch_input(&model, batch, 0xAB);
            let mut sa: Arena<f64> = Arena::new();
            let scalar = plan
                .execute_batch_path::<f64>(&(), &flat, batch, &mut sa, KernelPath::Scalar)
                .unwrap()
                .to_vec();
            let mut ba: Arena<f64> = Arena::new();
            let blocked = plan
                .execute_batch_path::<f64>(&(), &flat, batch, &mut ba, KernelPath::Blocked)
                .unwrap()
                .to_vec();
            assert_bits_eq(&scalar, &blocked, &format!("{} B={batch}", model.name));
        }
    }
}

// ---- escape hatches -------------------------------------------------------

#[test]
fn env_value_parser_controls_the_default_path() {
    // The pure parser behind RIGOR_FORCE_SCALAR (tested without mutating
    // process-global env, which would race parallel tests).
    use std::ffi::OsStr;
    assert_eq!(KernelPath::from_env_value(None), KernelPath::Blocked);
    assert_eq!(KernelPath::from_env_value(Some(OsStr::new(""))), KernelPath::Blocked);
    assert_eq!(KernelPath::from_env_value(Some(OsStr::new("0"))), KernelPath::Blocked);
    assert_eq!(KernelPath::from_env_value(Some(OsStr::new("1"))), KernelPath::Scalar);
    assert_eq!(KernelPath::from_env_value(Some(OsStr::new("yes"))), KernelPath::Scalar);
}

#[test]
fn parallelism_env_value_parser_controls_the_worker_count() {
    // The pure parser behind RIGOR_WORKERS, mirroring the kernel-path
    // parser above: unset / empty / "0" defer to the caller's default,
    // "1" pins serial drives, garbage falls back to the default.
    use rigor::plan::Parallelism;
    use std::ffi::OsStr;
    assert_eq!(Parallelism::from_env_value(None, 6).workers, 6);
    assert_eq!(Parallelism::from_env_value(Some(OsStr::new("")), 6).workers, 6);
    assert_eq!(Parallelism::from_env_value(Some(OsStr::new("0")), 6).workers, 6);
    assert_eq!(Parallelism::from_env_value(Some(OsStr::new("1")), 6).workers, 1);
    assert_eq!(Parallelism::from_env_value(Some(OsStr::new("4")), 6).workers, 4);
    assert_eq!(Parallelism::from_env_value(Some(OsStr::new(" 2 ")), 6).workers, 2);
    assert_eq!(Parallelism::from_env_value(Some(OsStr::new("lots")), 6).workers, 6);
    // A degenerate default is still clamped to a usable worker count.
    assert_eq!(Parallelism::from_env_value(None, 0).workers, 1);
}

#[test]
fn scalar_compiled_plans_degrade_blocked_requests() {
    // A plan compiled at Scalar carries no blocked data: requesting the
    // blocked path must silently run scalar, not panic.
    let model = zoo::tiny_cnn(3);
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Scalar).unwrap();
    assert_eq!(plan.kernel_path(), KernelPath::Scalar);
    let x = batch_input(&model, 4, 9);
    let mut a: Arena<f64> = Arena::new();
    let forced = plan
        .execute_batch_path::<f64>(&(), &x, 4, &mut a, KernelPath::Blocked)
        .unwrap()
        .to_vec();
    let mut b: Arena<f64> = Arena::new();
    let scalar = plan
        .execute_batch_path::<f64>(&(), &x, 4, &mut b, KernelPath::Scalar)
        .unwrap()
        .to_vec();
    assert_bits_eq(&scalar, &forced, "scalar-compiled plan");
}

#[test]
fn forced_scalar_request_round_trips_through_serve() {
    // The AnalysisRequest escape hatch: a forced-scalar serve must
    // deliver bit-identical outputs to the default (blocked) serve.
    let session = Session::builder().workers(2).build();
    let mk = |force: bool| {
        AnalysisRequest::builder()
            .model(zoo::tiny_cnn(7))
            .input_box()
            .max_batch(4)
            .max_wait_ms(1)
            .force_scalar_kernels(force)
            .build()
            .unwrap()
    };
    let forced_req = mk(true);
    assert!(forced_req.force_scalar_kernels());
    let n: usize = zoo::tiny_cnn(7).input_shape.iter().product();
    let sample = |i: usize| -> Vec<f64> { (0..n).map(|j| ((i + j) % 13) as f64 / 13.0).collect() };

    let blocked_out: Vec<Vec<f64>> = {
        let batcher = session.serve(&mk(false)).unwrap();
        let tickets: Vec<_> = (0..6).map(|i| batcher.submit(sample(i)).unwrap()).collect();
        tickets.into_iter().map(|t| t.wait().unwrap()).collect()
    };
    let scalar_out: Vec<Vec<f64>> = {
        let batcher = session.serve(&forced_req).unwrap();
        let tickets: Vec<_> = (0..6).map(|i| batcher.submit(sample(i)).unwrap()).collect();
        tickets.into_iter().map(|t| t.wait().unwrap()).collect()
    };
    for (i, (b, s)) in blocked_out.iter().zip(&scalar_out).enumerate() {
        assert_bits_eq(s, b, &format!("served sample {i}"));
    }
}

// ---- arena reservation ----------------------------------------------------

#[test]
fn arena_reservation_is_monotonic_high_water() {
    let model = zoo::tiny_mlp(1);
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let mut arena: Arena<f64> = Arena::new();
    arena.reserve_for_batch(&plan, 32);
    let hw: Vec<usize> = (0..plan.buffer_count()).map(|i| arena.reserved_len(i)).collect();
    assert_eq!(hw[0], plan.buffer_lens()[0] * 32);
    // A smaller batch must not lower any reservation.
    arena.reserve_for_batch(&plan, 3);
    for (i, &h) in hw.iter().enumerate() {
        assert_eq!(arena.reserved_len(i), h, "buffer {i} reservation shrank");
    }
    // A larger one raises it.
    arena.reserve_for_batch(&plan, 64);
    assert_eq!(arena.reserved_len(0), plan.buffer_lens()[0] * 64);
}

#[test]
fn steady_state_batched_execution_is_allocation_free() {
    // The serving steady state: one warmed arena, flushes of fluctuating
    // batch size. After warmup at the high-water batch, *zero* heap
    // allocations may happen on this thread across further drives —
    // including shrink-then-regrow sequences (the monotonic-reservation
    // bugfix) and the blocked kernels' panel scratch.
    let model = zoo::tiny_cnn(9);
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let big = batch_input(&model, 32, 1);
    let n: usize = model.input_shape.iter().product();
    let small = &big[..7 * n];
    let mut arena: Arena<f64> = Arena::new();
    for _ in 0..2 {
        plan.execute_batch::<f64>(&(), &big, 32, &mut arena).unwrap();
    }
    plan.execute_batch::<f64>(&(), small, 7, &mut arena).unwrap();

    let before = thread_allocs();
    for _ in 0..5 {
        plan.execute_batch::<f64>(&(), small, 7, &mut arena).unwrap();
        plan.execute_batch::<f64>(&(), &big, 32, &mut arena).unwrap();
        plan.execute_batch::<f64>(&(), &big[..n], 1, &mut arena).unwrap();
    }
    let allocs = thread_allocs() - before;
    assert_eq!(allocs, 0, "steady-state batched execution performed {allocs} allocations");
}

#[test]
fn sharded_tile_ranges_are_allocation_free_and_bit_identical_when_warm() {
    // The parallel executor's per-worker contract, measured at the kernel
    // level on this thread: once the panel scratch is warmed, driving a
    // dense step tile-range-by-tile-range performs zero heap allocations
    // and reproduces the full-range drive bit for bit, for every
    // partition point.
    use rigor::layers::gemm::{dense_blocked_tiles, DensePanel};
    use rigor::tensor::Tensor;
    let (m, n, batch) = (29usize, 13usize, 21usize); // prime-ish: row and lane tails
    let mut rng = Rng::new(0x5AD);
    let w = Tensor::new(vec![m, n], (0..m * n).map(|_| rng.range(-1.0, 1.0)).collect());
    let bias: Vec<f64> = (0..m).map(|_| rng.range(-1.0, 1.0)).collect();
    let x: Vec<f64> = (0..batch * n).map(|_| rng.range(-1.0, 1.0)).collect();
    let pd = DensePanel::pack(&w);
    let units = pd.tiles(batch);
    assert!(units >= 3, "need several tiles to partition");

    let mut pack: Vec<f64> = Vec::new();
    let mut full = vec![0.0f64; batch * m];
    dense_blocked_tiles(&(), &pd, &bias, &x, batch, 0, units, &mut pack, &mut full);

    // Bit-identity at every partition point.
    let mut sharded = vec![0.0f64; batch * m];
    for split in 1..units {
        sharded.iter_mut().for_each(|v| *v = 0.0);
        let (lo, hi) = sharded.split_at_mut(pd.tile_out_start(batch, split));
        dense_blocked_tiles(&(), &pd, &bias, &x, batch, 0, split, &mut pack, lo);
        dense_blocked_tiles(&(), &pd, &bias, &x, batch, split, units, &mut pack, hi);
        assert_bits_eq(&full, &sharded, &format!("dense split at tile {split}"));
    }

    // Zero allocations once warm (asserts above allocate their messages,
    // so the counted pass runs the bare kernel calls only).
    let before = thread_allocs();
    for split in 1..units {
        let (lo, hi) = sharded.split_at_mut(pd.tile_out_start(batch, split));
        dense_blocked_tiles(&(), &pd, &bias, &x, batch, 0, split, &mut pack, lo);
        dense_blocked_tiles(&(), &pd, &bias, &x, batch, split, units, &mut pack, hi);
    }
    let allocs = thread_allocs() - before;
    assert_eq!(allocs, 0, "warm tile-range drives performed {allocs} allocations");
}
