//! Whole-model soundness: CAA bounds versus actual emulated-precision
//! errors, across the zoo models, many precisions and many inputs — the
//! rigor contract of the paper at system scale (E-soundness without
//! artifacts; the artifact-based variant lives in the soundness_sweep
//! bench).

use rigor::caa::Ctx;
use rigor::model::{zoo, Model};
use rigor::prop;
use rigor::quant::{unit_roundoff, EmulatedFp};
use rigor::tensor::{EmuCtx, Tensor};

fn check_model_soundness(model: &Model, sample: &[f64], ks: &[u32]) {
    let ctx = Ctx::new(); // paper default u_max = 2^-7, rounded inputs
    let xr = Tensor::new(model.input_shape.clone(), sample.to_vec());
    let yr = model.forward::<f64>(&(), xr).unwrap();

    // The CAA forward gives per-output bounds (the aggregate path through
    // `api::Session` is exercised by integration.rs and the soundness_sweep
    // bench; here we want elementwise checks).
    let input = rigor::analysis::caa_input(&ctx, &model.input_shape, sample, 0.0);
    let yc = model
        .forward::<rigor::caa::Caa>(&ctx, input)
        .unwrap();

    for &k in ks {
        let ec = EmuCtx { k };
        let xe = Tensor::new(
            model.input_shape.clone(),
            sample.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
        );
        let ye = model.forward::<EmulatedFp>(&ec, xe).unwrap();
        let u = unit_roundoff(k);
        for i in 0..yr.len() {
            let err = (ye.data()[i].v - yr.data()[i]).abs();
            let bound = yc.data()[i].abs_bound();
            assert!(
                err <= bound * u * (1.0 + 1e-9) + 1e-10,
                "{} output {i} k={k}: |err| {err:.3e} > δ̄·u = {:.3e} (δ̄ = {bound})",
                model.name,
                bound * u,
            );
        }
    }
}

#[test]
fn mlp_sound_across_precisions_and_inputs() {
    prop::check_with(
        prop::Config { cases: 12, base_seed: 0x50FA },
        "mlp-soundness",
        |rng| {
            let model = zoo::scaled_mlp(rng.next_u64(), 24, 16, 6);
            let sample: Vec<f64> = (0..24).map(|_| rng.range(0.0, 1.0)).collect();
            check_model_soundness(&model, &sample, &[8, 10, 13, 17, 22]);
        },
    );
}

#[test]
fn cnn_sound_across_precisions() {
    prop::check_with(
        prop::Config { cases: 5, base_seed: 0x50FB },
        "cnn-soundness",
        |rng| {
            let model = zoo::tiny_cnn(rng.next_u64());
            let n: usize = model.input_shape.iter().product();
            let sample: Vec<f64> = (0..n).map(|_| rng.range(0.0, 1.0)).collect();
            check_model_soundness(&model, &sample, &[8, 12, 18]);
        },
    );
}

#[test]
fn pendulum_sound_across_precisions() {
    prop::check_with(
        prop::Config { cases: 8, base_seed: 0x50FC },
        "pendulum-soundness",
        |rng| {
            let model = zoo::tiny_pendulum(rng.next_u64());
            let sample = vec![rng.range(-6.0, 6.0), rng.range(-6.0, 6.0)];
            check_model_soundness(&model, &sample, &[8, 11, 16, 24]);
        },
    );
}

#[test]
fn box_analysis_encloses_every_point_in_the_box() {
    // An input-box analysis must dominate point runs anywhere in the box.
    let model = zoo::tiny_pendulum(99);
    let ctx = Ctx::new();
    let center = [1.0, -2.0];
    let input = rigor::analysis::caa_input_cfg(&ctx, &model.input_shape, &center, 0.5, false);
    let yc = model.forward::<rigor::caa::Caa>(&ctx, input).unwrap();

    let mut rng = rigor::util::Rng::new(5);
    for _ in 0..50 {
        let p = [
            center[0] + rng.range(-0.5, 0.5),
            center[1] + rng.range(-0.5, 0.5),
        ];
        let yr = model
            .forward::<f64>(&(), Tensor::new(vec![2], p.to_vec()))
            .unwrap();
        assert!(
            yc.data()[0].ideal().inflate(1e-9).contains(yr.data()[0]),
            "point run {} outside box ideal {}",
            yr.data()[0],
            yc.data()[0].ideal()
        );
        for k in [8u32, 12] {
            let ec = EmuCtx { k };
            let xe = Tensor::new(vec![2], p.iter().map(|&v| EmulatedFp::new(v, k)).collect());
            let ye = model.forward::<EmulatedFp>(&ec, xe).unwrap();
            assert!(
                yc.data()[0].rounded().inflate(1e-9).contains(ye.data()[0].v),
                "emulated k={k} run outside box rounded range"
            );
        }
    }
}
