//! Golden snapshot suite for the textual Plan IR.
//!
//! Every zoo model is compiled for every serve format the repo
//! exercises (native `f64` and one emulated precision) and for both
//! kernel families, then rendered with [`Plan::to_text`] and compared
//! byte-for-byte against the checked-in snapshot in
//! `rust/tests/golden/<model>__<format>__<kernels>.plan`.
//!
//! On a mismatch the failure message leads with the *structural* edit
//! list from [`rigor::plan::diff`] — "step s3 changed: act relu -> -" —
//! so compiler drift reads as a reviewable plan change, not a wall of
//! text. The full actual rendering follows for context.
//!
//! To bless intentional changes, regenerate the snapshots in place:
//!
//! ```text
//! RIGOR_BLESS=1 cargo test --test golden
//! ```

use rigor::model::{zoo, Model};
use rigor::plan::{diff, KernelPath, Plan, PlanText, ServeFormat};

use std::path::PathBuf;

/// The whole zoo. Seeds only affect weight values, which the IR never
/// prints — structure is a function of (architecture, format, kernels).
fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::avgpool_cnn(3),
        zoo::tiny_pendulum(4),
        zoo::scaled_mlp(5, 16, 24, 5),
        zoo::residual_mlp(6),
        zoo::residual_cnn(7),
    ]
}

/// Format tags double as file-name components and [`ServeFormat`]
/// spellings: native f64 compiles at `Fusion::Full`, the emulated
/// format at `Fusion::None` (the analysis-faithful trace).
const FORMATS: [&str; 2] = ["f64", "emu-k12"];

const KERNELS: [(KernelPath, &str); 2] =
    [(KernelPath::Blocked, "blocked"), (KernelPath::Scalar, "scalar")];

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn bless() -> bool {
    std::env::var("RIGOR_BLESS").as_deref() == Ok("1")
}

/// Render the plan for one (model, format, kernels) cell.
fn render(model: &Model, format: &str, path: KernelPath) -> String {
    let fmt: ServeFormat = format.parse().expect("format tag parses");
    Plan::for_format_with_kernels(model, fmt, path)
        .unwrap_or_else(|e| panic!("compile {} {format}: {e}", model.name))
        .to_text()
}

#[test]
fn golden_plan_ir_snapshots() {
    let dir = golden_dir();
    let mut failures = Vec::new();
    for model in zoo_models() {
        for format in FORMATS {
            for (path, tag) in KERNELS {
                let actual = render(&model, format, path);
                let file = dir.join(format!("{}__{format}__{tag}.plan", model.name));
                if bless() {
                    std::fs::write(&file, &actual)
                        .unwrap_or_else(|e| panic!("bless {}: {e}", file.display()));
                    continue;
                }
                let expected = match std::fs::read_to_string(&file) {
                    Ok(text) => text,
                    Err(e) => {
                        failures.push(format!(
                            "missing golden {}: {e} (regenerate with RIGOR_BLESS=1)",
                            file.display()
                        ));
                        continue;
                    }
                };
                if expected == actual {
                    continue;
                }
                let old = PlanText::parse(&expected)
                    .unwrap_or_else(|e| panic!("golden {} unparseable: {e}", file.display()));
                let new = PlanText::parse(&actual).expect("rendered IR parses");
                let edits = diff(&old, &new);
                let mut msg = format!("golden drift in {}:\n", file.display());
                if edits.is_empty() {
                    msg.push_str("  (no structural edits — byte-level drift only)\n");
                } else {
                    for edit in &edits {
                        msg.push_str(&format!("  {edit}\n"));
                    }
                }
                msg.push_str("actual plan IR:\n");
                msg.push_str(&actual);
                msg.push_str("(bless intentional changes with RIGOR_BLESS=1)");
                failures.push(msg);
            }
        }
    }
    assert!(failures.is_empty(), "{}", failures.join("\n\n"));
}

/// Two consecutive compiles of the same model must render
/// byte-identically — the determinism contract the snapshots (and the
/// plan cache keys) rest on.
#[test]
fn consecutive_compiles_are_byte_identical() {
    for model in zoo_models() {
        for format in FORMATS {
            for (path, tag) in KERNELS {
                let a = render(&model, format, path);
                let b = render(&model, format, path);
                assert_eq!(a, b, "{} {format} {tag}: non-deterministic compile", model.name);
            }
        }
    }
}

/// Every checked-in snapshot corresponds to a live (model, format,
/// kernels) cell and parses under the current grammar — catches stale
/// files left behind by a rename as well as hand-edited corruption.
#[test]
fn golden_directory_is_exactly_the_matrix() {
    if bless() {
        return; // the bless run may be mid-rewrite
    }
    let mut expected: Vec<String> = Vec::new();
    for model in zoo_models() {
        for format in FORMATS {
            for (_, tag) in KERNELS {
                expected.push(format!("{}__{format}__{tag}.plan", model.name));
            }
        }
    }
    expected.sort();
    let mut found: Vec<String> = std::fs::read_dir(golden_dir())
        .expect("golden dir exists")
        .map(|e| e.expect("dir entry").file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".plan"))
        .collect();
    found.sort();
    assert_eq!(found, expected, "golden dir out of sync with the zoo matrix");
    for name in &found {
        let text = std::fs::read_to_string(golden_dir().join(name)).unwrap();
        PlanText::parse(&text)
            .unwrap_or_else(|e| panic!("golden {name} does not parse: {e}"));
    }
}
