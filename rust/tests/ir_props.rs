//! Property tests for the plan compiler and its textual IR, over
//! seeded random branchy graphs.
//!
//! The generator grows a DAG of dense / activation / `Add` / `Concat`
//! layers over 1-D values, keeping a *frontier* of live values: an op
//! either replaces its operand (a chain) or leaves it live (a branch,
//! which some later op re-reads — a skip edge). A final merge chain
//! drains the frontier so every layer contributes to the output. No
//! batch norm is generated, so every fusion level computes the exact
//! same arithmetic and all fusion x kernel combinations must agree
//! bit-for-bit — any buffer-recycling bug (read-after-free, clobbered
//! merge operand) shows up as a bit difference.
//!
//! Structural invariants are checked on both the compiled plan and its
//! parsed IR; every failure message leads with the generator seed.

use rigor::layers::Layer;
use rigor::model::{zoo, Graph, Model};
use rigor::plan::{diff, Arena, Fusion, KernelPath, Plan, PlanText};
use rigor::util::Rng;

/// Grow a random branchy model. Structure and weights are a pure
/// function of `seed`.
fn random_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let input_width = rng.int_range(2, 8) as usize;
    let mut layers: Vec<Layer> = Vec::new();
    let mut names: Vec<String> = Vec::new();
    let mut inbound: Vec<Vec<String>> = Vec::new();
    // Live values: (node name, vector width).
    let mut frontier: Vec<(String, usize)> = vec![("input".to_string(), input_width)];

    let push = |layers: &mut Vec<Layer>,
                    names: &mut Vec<String>,
                    inbound: &mut Vec<Vec<String>>,
                    layer: Layer,
                    feeds: Vec<String>|
     -> String {
        let name = format!("n{}", layers.len());
        layers.push(layer);
        names.push(name.clone());
        inbound.push(feeds);
        name
    };

    let ops = rng.int_range(4, 12);
    for _ in 0..ops {
        match rng.below(5) {
            // Dense from a random live value; half the time the result
            // replaces its operand (chain), otherwise both stay live
            // (branch: the operand gains a second consumer later).
            0 | 1 => {
                let i = rng.below(frontier.len());
                let (src, width) = frontier[i].clone();
                let units = rng.int_range(2, 8) as usize;
                let name = push(
                    &mut layers,
                    &mut names,
                    &mut inbound,
                    zoo::dense(&mut rng, width, units),
                    vec![src],
                );
                if rng.bool(0.5) {
                    frontier[i] = (name, units);
                } else {
                    frontier.push((name, units));
                }
            }
            // Elementwise activation (in-place-aliasable step).
            2 => {
                let i = rng.below(frontier.len());
                let (src, width) = frontier[i].clone();
                let act = if rng.bool(0.5) { Layer::Relu } else { Layer::Tanh };
                let name = push(&mut layers, &mut names, &mut inbound, act, vec![src]);
                if rng.bool(0.7) {
                    frontier[i] = (name, width);
                } else {
                    frontier.push((name, width));
                }
            }
            // Add two equal-width live values (both consumed).
            3 => {
                let pair = (0..frontier.len())
                    .flat_map(|a| ((a + 1)..frontier.len()).map(move |b| (a, b)))
                    .find(|&(a, b)| frontier[a].1 == frontier[b].1);
                if let Some((a, b)) = pair {
                    let (na, width) = frontier[a].clone();
                    let (nb, _) = frontier[b].clone();
                    frontier.remove(b); // b > a: remove the later index first
                    frontier.remove(a);
                    let name =
                        push(&mut layers, &mut names, &mut inbound, Layer::Add, vec![na, nb]);
                    frontier.push((name, width));
                }
            }
            // Concat two distinct live values (both consumed).
            _ => {
                if frontier.len() >= 2 {
                    let a = rng.below(frontier.len() - 1);
                    let b = a + 1 + rng.below(frontier.len() - a - 1);
                    let (na, wa) = frontier[a].clone();
                    let (nb, wb) = frontier[b].clone();
                    frontier.remove(b);
                    frontier.remove(a);
                    let name =
                        push(&mut layers, &mut names, &mut inbound, Layer::Concat, vec![na, nb]);
                    frontier.push((name, wa + wb));
                }
            }
        }
    }

    // Drain the frontier so every branch reaches the output.
    while frontier.len() > 1 {
        let (na, wa) = frontier.remove(0);
        let (nb, wb) = frontier.remove(0);
        let name = push(&mut layers, &mut names, &mut inbound, Layer::Concat, vec![na, nb]);
        frontier.push((name, wa + wb));
    }
    let (head, width) = frontier.pop().unwrap();
    let dense = zoo::dense(&mut rng, width, 3);
    let out = push(&mut layers, &mut names, &mut inbound, dense, vec![head]);
    let out = push(&mut layers, &mut names, &mut inbound, Layer::Softmax, vec![out]);

    Model {
        name: format!("prop_{seed}"),
        input_shape: vec![input_width],
        layers,
        graph: Some(Graph { names, inbound, output: Some(out) }),
    }
}

/// Structural invariants on a compiled plan and its rendered IR.
fn check_structure(plan: &Plan, what: &str) {
    // step_deps: strictly backward edges, deduped, ascending — acyclic
    // by construction, and stable for the differ.
    for (i, deps) in plan.step_deps().iter().enumerate() {
        for (k, &d) in deps.iter().enumerate() {
            assert!(d < i, "{what}: s{i} dep s{d} not a predecessor");
            if k > 0 {
                assert!(deps[k - 1] < d, "{what}: s{i} deps not ascending/deduped");
            }
        }
    }
    // Merge steps never alias an operand in place: a clobbered operand
    // would corrupt the other input mid-sum.
    for (i, step) in plan.steps().iter().enumerate() {
        if step.inputs.len() >= 2 {
            assert!(
                !step.inputs.contains(&step.out),
                "{what}: merge step s{i} writes one of its own inputs"
            );
        }
    }
    // No read-after-free: every buffer a step reads is either the plan
    // input buffer or was written by an earlier step.
    let text = PlanText::of(plan);
    let input_buf: usize = text.input.split_whitespace().next().unwrap()[1..]
        .parse()
        .expect("input header starts with b<i>");
    let mut written = vec![false; plan.buffer_count()];
    written[input_buf] = true;
    for (i, step) in plan.steps().iter().enumerate() {
        for &b in &step.inputs {
            assert!(written[b], "{what}: s{i} reads b{b} before any write");
        }
        written[step.out] = true;
    }
}

/// Round-trip and determinism invariants on the textual form.
fn check_text(plan: &Plan, what: &str) {
    let text = plan.to_text();
    let parsed = PlanText::parse(&text).unwrap_or_else(|e| panic!("{what}: parse: {e}"));
    assert_eq!(parsed.render(), text, "{what}: to_text -> parse -> render not byte-identical");
    let again = PlanText::parse(&plan.to_text()).unwrap();
    assert!(diff(&parsed, &again).is_empty(), "{what}: self-diff not empty");
}

const SEEDS: std::ops::Range<u64> = 0..40;

#[test]
fn random_graphs_compile_with_sound_structure() {
    for seed in SEEDS {
        let model = random_model(seed);
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            for path in [KernelPath::Scalar, KernelPath::Blocked] {
                let what = format!("seed {seed} {fusion:?} {path:?}");
                let plan = Plan::build_with_kernels(&model, fusion, path)
                    .unwrap_or_else(|e| panic!("{what}: build: {e}"));
                check_structure(&plan, &what);
                check_text(&plan, &what);
            }
        }
    }
}

#[test]
fn random_graphs_agree_bitwise_across_fusion_and_kernels() {
    for seed in SEEDS {
        let model = random_model(seed);
        let n: usize = model.input_shape.iter().product();
        let mut rng = Rng::new(seed ^ 0xDEAD);
        let input: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut reference: Option<Vec<f64>> = None;
        for fusion in [Fusion::None, Fusion::Pair, Fusion::Full] {
            for path in [KernelPath::Scalar, KernelPath::Blocked] {
                let plan = Plan::build_with_kernels(&model, fusion, path).unwrap();
                let mut arena: Arena<f64> = Arena::new();
                let out = plan.execute::<f64>(&(), &input, &mut arena).unwrap().to_vec();
                match &reference {
                    None => reference = Some(out),
                    Some(want) => {
                        assert_eq!(want.len(), out.len(), "seed {seed}: output length");
                        for (i, (a, b)) in want.iter().zip(&out).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "seed {seed} {fusion:?} {path:?}: element {i} ({a} vs {b})"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn random_graphs_compile_deterministically() {
    for seed in SEEDS.step_by(4) {
        let model = random_model(seed);
        let a = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
        let b = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
        assert_eq!(a.to_text(), b.to_text(), "seed {seed}: non-deterministic compile");
    }
}
