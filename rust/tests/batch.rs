//! Integration tests for the **batch axis** (PR "batched execution
//! subsystem"):
//!
//! * `B = 1` bit-identity: `Plan::execute_batch(.., 1, ..)` reproduces the
//!   single-sample executor exactly, across the whole zoo, for the f64
//!   trace and for CAA bounds;
//! * `B > 1` per-sample equality: every sample of a batched drive is
//!   bit-identical to its own independent single run — f64, emulated-k
//!   witness, and CAA — including the residual (graph) models;
//! * the bulk front doors: `Session::run_batch` per-sample outcomes equal
//!   per-sample analyses, and the `serve::MicroBatcher` resolves bulk
//!   traffic to exactly the plan's f64 traces under batching pressure.

use rigor::api::{AnalysisRequest, ExecMode, Session};
use rigor::caa::{Caa, Ctx};
use rigor::data::Dataset;
use rigor::interval::Interval;
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, Plan};
use rigor::quant::EmulatedFp;
use rigor::tensor::EmuCtx;
use rigor::util::Rng;
use std::sync::Arc;

/// Every zoo topology: sequential chains and both graph (residual/branchy)
/// models.
fn whole_zoo() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::tiny_pendulum(3),
        zoo::scaled_mlp(4, 32, 24, 10),
        zoo::residual_mlp(5),
        zoo::residual_cnn(6),
    ]
}

fn samples_for(model: &Model, count: usize, seed: u64) -> Vec<Vec<f64>> {
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| (0..n).map(|_| rng.range(0.0, 1.0)).collect())
        .collect()
}

fn caa_point_input(ctx: &Ctx, sample: &[f64]) -> Vec<Caa> {
    sample
        .iter()
        .map(|&v| Caa::input(ctx, Interval::point(v), v))
        .collect()
}

#[test]
fn b1_f64_bit_identical_to_single_sample_executor_across_zoo() {
    for model in whole_zoo() {
        for plan in [Plan::for_analysis(&model).unwrap(), Plan::unfused(&model).unwrap()] {
            let x = samples_for(&model, 1, 7).remove(0);
            let mut single_arena: Arena<f64> = Arena::new();
            let single = plan.execute::<f64>(&(), &x, &mut single_arena).unwrap().to_vec();
            let mut batch_arena: Arena<f64> = Arena::new();
            let batched = plan.execute_batch::<f64>(&(), &x, 1, &mut batch_arena).unwrap();
            assert_eq!(batched.len(), single.len(), "{}", model.name);
            for (i, (b, s)) in batched.iter().zip(&single).enumerate() {
                assert_eq!(b.to_bits(), s.to_bits(), "{} output {i}", model.name);
            }
        }
    }
}

#[test]
fn b1_caa_bounds_bit_identical_across_zoo() {
    let ctx = Ctx::new();
    for model in whole_zoo() {
        let plan = Plan::for_analysis(&model).unwrap();
        let x = samples_for(&model, 1, 8).remove(0);
        let input = caa_point_input(&ctx, &x);
        let mut single_arena: Arena<Caa> = Arena::new();
        let single = plan.execute::<Caa>(&ctx, &input, &mut single_arena).unwrap().to_vec();
        let mut batch_arena: Arena<Caa> = Arena::new();
        let batched = plan.execute_batch::<Caa>(&ctx, &input, 1, &mut batch_arena).unwrap();
        assert_eq!(batched.len(), single.len(), "{}", model.name);
        for (i, (b, s)) in batched.iter().zip(&single).enumerate() {
            assert_eq!(b.fp().to_bits(), s.fp().to_bits(), "{} output {i}: trace", model.name);
            assert_eq!(
                b.abs_bound().to_bits(),
                s.abs_bound().to_bits(),
                "{} output {i}: abs bound",
                model.name
            );
            assert_eq!(
                b.rel_bound().to_bits(),
                s.rel_bound().to_bits(),
                "{} output {i}: rel bound",
                model.name
            );
        }
    }
}

#[test]
fn b5_f64_per_sample_equality_with_independent_runs() {
    const B: usize = 5;
    for model in whole_zoo() {
        let plan = Plan::for_analysis(&model).unwrap();
        let samples = samples_for(&model, B, 9);
        let flat: Vec<f64> = samples.concat();
        let mut batch_arena: Arena<f64> = Arena::new();
        let batched = plan.execute_batch::<f64>(&(), &flat, B, &mut batch_arena).unwrap();
        let m = plan.output_len();
        assert_eq!(batched.len(), B * m, "{}", model.name);
        let batched = batched.to_vec();
        let mut arena: Arena<f64> = Arena::new();
        for (s, sample) in samples.iter().enumerate() {
            let single = plan.execute::<f64>(&(), sample, &mut arena).unwrap();
            for (i, (b, w)) in batched[s * m..(s + 1) * m].iter().zip(single).enumerate() {
                assert_eq!(b.to_bits(), w.to_bits(), "{} sample {s} output {i}", model.name);
            }
        }
    }
}

#[test]
fn b3_caa_per_sample_bounds_equal_independent_runs_including_residual() {
    const B: usize = 3;
    let ctx = Ctx::new();
    // Explicitly include both graph models next to a sequential chain: the
    // acceptance case for merge steps under the batch axis.
    for model in [zoo::scaled_mlp(11, 16, 12, 4), zoo::residual_mlp(12), zoo::residual_cnn(13)] {
        let plan = Plan::for_analysis(&model).unwrap();
        let samples = samples_for(&model, B, 10);
        let flat: Vec<Caa> = samples
            .iter()
            .flat_map(|s| caa_point_input(&ctx, s))
            .collect();
        let mut batch_arena: Arena<Caa> = Arena::new();
        let batched =
            plan.execute_batch::<Caa>(&ctx, &flat, B, &mut batch_arena).unwrap().to_vec();
        let m = plan.output_len();
        let mut arena: Arena<Caa> = Arena::new();
        for (s, sample) in samples.iter().enumerate() {
            let input = caa_point_input(&ctx, sample);
            let single = plan.execute::<Caa>(&ctx, &input, &mut arena).unwrap();
            for (i, (b, w)) in batched[s * m..(s + 1) * m].iter().zip(single).enumerate() {
                assert_eq!(
                    b.abs_bound().to_bits(),
                    w.abs_bound().to_bits(),
                    "{} sample {s} output {i}: abs bound",
                    model.name
                );
                assert_eq!(
                    b.rel_bound().to_bits(),
                    w.rel_bound().to_bits(),
                    "{} sample {s} output {i}: rel bound",
                    model.name
                );
                assert_eq!(
                    b.fp().to_bits(),
                    w.fp().to_bits(),
                    "{} sample {s} output {i}: trace",
                    model.name
                );
            }
        }
    }
}

#[test]
fn b4_emulated_witness_per_sample_equality() {
    const B: usize = 4;
    let k = 10u32;
    let ec = EmuCtx { k };
    for model in [zoo::tiny_cnn(21), zoo::residual_cnn(22)] {
        // Unfused: the witness flavor sampling_estimate drives.
        let plan = Plan::unfused(&model).unwrap();
        let samples = samples_for(&model, B, 11);
        let flat: Vec<EmulatedFp> = samples
            .iter()
            .flat_map(|s| s.iter().map(|&v| EmulatedFp::new(v, k)))
            .collect();
        let mut batch_arena: Arena<EmulatedFp> = Arena::new();
        let batched =
            plan.execute_batch::<EmulatedFp>(&ec, &flat, B, &mut batch_arena).unwrap().to_vec();
        let m = plan.output_len();
        let mut arena: Arena<EmulatedFp> = Arena::new();
        for (s, sample) in samples.iter().enumerate() {
            let xe: Vec<EmulatedFp> = sample.iter().map(|&v| EmulatedFp::new(v, k)).collect();
            let single = plan.execute::<EmulatedFp>(&ec, &xe, &mut arena).unwrap();
            for (i, (b, w)) in batched[s * m..(s + 1) * m].iter().zip(single).enumerate() {
                assert_eq!(
                    b.v.to_bits(),
                    w.v.to_bits(),
                    "{} sample {s} output {i}",
                    model.name
                );
            }
        }
    }
}

#[test]
fn execute_batch_validates_geometry() {
    let plan = Plan::for_analysis(&zoo::tiny_mlp(2)).unwrap();
    let mut arena: Arena<f64> = Arena::new();
    assert!(plan.execute_batch::<f64>(&(), &[0.0; 16], 0, &mut arena).is_err(), "batch 0");
    assert!(
        plan.execute_batch::<f64>(&(), &[0.0; 15], 2, &mut arena).is_err(),
        "length mismatch"
    );
}

#[test]
fn arena_alternates_between_batched_and_single_use() {
    // One worker arena serves single runs and batched runs interleaved —
    // the serving reality — without cross-talk.
    let model = zoo::residual_mlp(31);
    let plan = Plan::for_analysis(&model).unwrap();
    let samples = samples_for(&model, 3, 12);
    let mut arena: Arena<f64> = Arena::new();
    let single_first = plan.execute::<f64>(&(), &samples[0], &mut arena).unwrap().to_vec();
    let flat: Vec<f64> = samples.concat();
    let batched = plan.execute_batch::<f64>(&(), &flat, 3, &mut arena).unwrap().to_vec();
    let single_again = plan.execute::<f64>(&(), &samples[0], &mut arena).unwrap().to_vec();
    assert_eq!(single_first, single_again);
    let m = plan.output_len();
    assert_eq!(&batched[..m], single_first.as_slice());
}

#[test]
fn run_batch_bulk_outcomes_match_per_sample_analysis_on_residual_model() {
    let model = zoo::residual_mlp(41);
    let data = Dataset {
        input_shape: model.input_shape.clone(),
        inputs: samples_for(&model, 7, 13),
        labels: vec![0, 1, 2, 0, 1, 2, 0],
    };
    let session = Session::builder().workers(2).build();
    for mode in [ExecMode::Serial, ExecMode::Pooled { workers: 0 }] {
        let req = AnalysisRequest::builder()
            .model(model.clone())
            .data(data.clone())
            .max_batch(3) // 7 samples -> chunks of 3, 3, 1
            .mode(mode)
            .build()
            .unwrap();
        let outcomes = session.run_batch(&req).unwrap();
        assert_eq!(outcomes.len(), 7, "{mode:?}");
        let plan = Plan::for_analysis(&model).unwrap();
        let cfg = req.analysis_config();
        for (i, out) in outcomes.iter().enumerate() {
            let want = rigor::analysis::analyze_class_with_plan(
                &plan,
                &cfg,
                data.labels[i],
                &data.inputs[i],
            )
            .unwrap();
            assert_eq!(out.analysis.per_class.len(), 1, "{mode:?} sample {i}");
            assert_eq!(out.analysis.per_class[0].class, data.labels[i]);
            assert_eq!(
                out.analysis.max_abs_u.to_bits(),
                want.max_abs_u.to_bits(),
                "{mode:?} sample {i}: abs bound"
            );
            assert_eq!(
                out.analysis.max_rel_u.to_bits(),
                want.max_rel_u.to_bits(),
                "{mode:?} sample {i}: rel bound"
            );
        }
    }
}

#[test]
fn micro_batcher_bulk_traffic_resolves_to_plan_traces() {
    let model = zoo::residual_mlp(51);
    let plan = Arc::new(Plan::for_analysis(&model).unwrap());
    let session = Session::builder().workers(2).build();
    let req = AnalysisRequest::builder()
        .model(model.clone())
        .input_box()
        .max_batch(4)
        .max_wait_ms(1)
        .build()
        .unwrap();
    let batcher = session.serve(&req).unwrap();
    let samples = samples_for(&model, 11, 14);
    let tickets: Vec<_> = samples
        .iter()
        .map(|s| batcher.submit(s.clone()).unwrap())
        .collect();
    let mut arena: Arena<f64> = Arena::new();
    for (s, t) in samples.iter().zip(tickets) {
        let got = t.wait().unwrap();
        let want = plan.execute::<f64>(&(), s, &mut arena).unwrap();
        assert_eq!(got, want, "served output must equal the direct plan trace");
    }
    let m = batcher.metrics();
    assert_eq!(m.submitted, 11);
    assert!(m.batches >= 3, "11 requests at max_batch 4 need >= 3 drives, saw {}", m.batches);
    assert!(m.max_batch_observed <= 4);
    // The session pool executed the batch jobs.
    assert!(session.pool().metrics().submitted >= m.batches);
}
