#!/usr/bin/env python3
"""Bootstrap generator + independent oracle for the textual-Plan-IR goldens.

The *authoritative* way to (re)generate the `.plan` snapshots in this
directory is the Rust test suite itself:

    RIGOR_BLESS=1 cargo test --test golden

This script is a from-scratch mirror of the plan compiler's structural
pipeline (toposort -> fold/pair fusion -> buffer assignment -> blocked
lowering -> hazard edges -> memory accounting) and of the `plan::ir`
renderer, kept as an independent cross-check: it must produce the exact
bytes `Plan::to_text()` renders, or one of the two implementations has a
structural bug. It also re-derives the per-row-class im2col tables
against the full per-pixel layout and asserts the memory-diet floor
(baseline >= 2x resident for the cached blocked residual_cnn) that
`rust/tests/memdiet.rs` pins.

Weights never appear in the IR (structure + parameter counts only), so
no RNG mirroring is needed.
"""

import math
import os

MR, NR = 4, 8
F64B = 8
USIZE = 8
PAD = object()  # sentinel; never rendered

# --------------------------------------------------------------------------
# Model zoo (structure only - dims, wiring; weights are irrelevant here)
# --------------------------------------------------------------------------


def dense(inp, units):
    return {"kind": "dense", "m": units, "n": inp}


def conv(kh, kw, cin, cout, stride, pad):
    return {"kind": "conv2d", "k": [kh, kw, cin, cout], "stride": stride, "pad": pad}


def dw(kh, kw, c, stride, pad):
    return {"kind": "depthwise_conv2d", "k": [kh, kw, c], "stride": stride, "pad": pad}


def bn(c):
    return {"kind": "batch_norm", "c": c, "eps": "0.001"}


def act(name):
    return {"kind": name}


def pool(kind, ph, pw):
    return {"kind": kind, "ph": ph, "pw": pw}


def seq(name, input_shape, layers):
    return {"name": name, "input_shape": input_shape, "layers": layers, "graph": None}


def tiny_mlp():
    return seq("tiny_mlp", [8], [dense(8, 6), act("relu"), dense(6, 4), act("relu"),
                                 dense(4, 3), act("softmax")])


def tiny_cnn():
    return seq("tiny_cnn", [6, 6, 1], [conv(3, 3, 1, 4, 1, "same"), bn(4), act("relu"),
                                       dw(3, 3, 4, 1, "same"), act("relu"),
                                       pool("max_pool2d", 2, 2), act("flatten"),
                                       dense(36, 5), act("softmax")])


def avgpool_cnn():
    m = tiny_cnn()
    m["name"] = "avgpool_cnn"
    m["layers"][5] = pool("avg_pool2d", 2, 2)
    return m


def tiny_pendulum():
    return seq("tiny_pendulum", [2], [dense(2, 8), act("tanh"), dense(8, 1), act("tanh")])


def scaled_mlp(inp, hidden, classes):
    return seq(f"mlp_{inp}_{hidden}_{classes}",
               [inp], [dense(inp, hidden), act("relu"), dense(hidden, hidden), act("relu"),
                       dense(hidden, classes), act("softmax")])


def residual_mlp():
    m = seq("residual_mlp", [8], [dense(8, 8), act("relu"), dense(8, 8), act("add"),
                                  act("relu"), dense(8, 3), act("softmax")])
    # inbound value ids (0 = model input, l+1 = output of layer l)
    m["graph"] = {"inputs": [[0], [1], [2], [3, 2], [4], [5], [6]], "output_val": 7}
    return m


def residual_cnn():
    m = seq("residual_cnn", [6, 6, 1],
            [conv(3, 3, 1, 4, 1, "same"), bn(4), act("relu"), conv(3, 3, 4, 4, 1, "same"),
             act("add"), act("relu"), conv(1, 1, 4, 2, 1, "same"), conv(3, 3, 4, 2, 1, "same"),
             act("concat"), act("relu"), pool("max_pool2d", 2, 2), act("flatten"),
             dense(36, 5), act("softmax")])
    m["graph"] = {"inputs": [[0], [1], [2], [3], [4, 3], [5], [6], [6], [7, 8], [9], [10],
                             [11], [12], [13]], "output_val": 14}
    return m


ZOO = [tiny_mlp, tiny_cnn, avgpool_cnn, tiny_pendulum,
       lambda: scaled_mlp(16, 24, 5), residual_mlp, residual_cnn]

MERGES = ("add", "concat")
ACTS = ("relu", "leaky_relu", "tanh", "sigmoid")

# --------------------------------------------------------------------------
# Geometry (mirrors layers::conv::pad_offsets / output shapes)
# --------------------------------------------------------------------------


def pad_offsets(h, w, kh, kw, stride, pad):
    if pad == "valid":
        return 0, 0, (h - kh) // stride + 1, (w - kw) // stride + 1
    oh, ow = -(-h // stride), -(-w // stride)
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - w, 0)
    return pad_h // 2, pad_w // 2, oh, ow


def out_shape_of(layer, in_shapes):
    k = layer["kind"]
    if k == "dense":
        return [layer["m"]]
    if k in ("conv2d", "depthwise_conv2d"):
        h, w = in_shapes[0][0], in_shapes[0][1]
        ks = layer["k"]
        _, _, oh, ow = pad_offsets(h, w, ks[0], ks[1], layer["stride"], layer["pad"])
        cout = ks[3] if k == "conv2d" else ks[2]
        return [oh, ow, cout]
    if k in ("max_pool2d", "avg_pool2d"):
        h, w, c = in_shapes[0]
        return [h // layer["ph"], w // layer["pw"], c]
    if k == "flatten":
        return [math.prod(in_shapes[0])]
    if k == "concat":
        return in_shapes[0][:-1] + [sum(s[-1] for s in in_shapes)]
    return list(in_shapes[0])  # bn, activations, softmax, add


# --------------------------------------------------------------------------
# Compile pipeline mirror (plan::build_with_kernels)
# --------------------------------------------------------------------------


def toposort(model):
    n = len(model["layers"])
    if model["graph"] is None:
        return list(range(n)), [[i] for i in range(n)], n
    inputs = model["graph"]["inputs"]
    indeg = [sum(1 for v in ins if v > 0) for ins in inputs]
    consumers = [[] for _ in range(n + 1)]
    for i, ins in enumerate(inputs):
        for v in ins:
            consumers[v].append(i)
    queue = [i for i in range(n) if indeg[i] == 0]
    order = []
    while queue:
        i = queue.pop(0)
        order.append(i)
        for c in consumers[i + 1]:
            indeg[c] -= 1
            if indeg[c] == 0:
                queue.append(c)
    assert len(order) == n, "cycle"
    return order, inputs, model["graph"]["output_val"]


def compile_plan(model, fusion, kernels):
    order, inputs, output_val = toposort(model)
    n = len(model["layers"])
    val_shape = [None] * (n + 1)
    val_shape[0] = list(model["input_shape"])
    for l in order:
        val_shape[l + 1] = out_shape_of(model["layers"][l],
                                        [val_shape[v] for v in inputs[l]])

    drafts = []
    for l in order:
        in_vals = list(inputs[l])
        layer = dict(model["layers"][l])
        layer["folded"] = False
        drafts.append({"layer": layer, "inputs": in_vals, "out_val": l + 1,
                       "in_shapes": [list(val_shape[v]) for v in in_vals],
                       "out_shape": list(val_shape[l + 1]), "act": None,
                       "lo": l, "hi": l + 1})

    uses = [0] * (n + 1)
    for d in drafts:
        for v in d["inputs"]:
            uses[v] += 1
    uses[output_val] += 1

    def producer_of(v):
        for p, d in enumerate(drafts):
            if d["out_val"] == v:
                return p
        return None

    if fusion == "full":
        i = 0
        while i < len(drafts):
            d = drafts[i]
            p = None
            if d["layer"]["kind"] == "batch_norm":
                v = d["inputs"][0]
                cand = producer_of(v)
                if (cand is not None and uses[v] == 1 and drafts[cand]["act"] is None
                        and drafts[cand]["layer"]["kind"] in ("dense", "conv2d",
                                                              "depthwise_conv2d")):
                    p = cand
            if p is None:
                i += 1
                continue
            bn_d = drafts.pop(i)
            prev = drafts[p]
            prev["layer"]["folded"] = True
            prev["out_val"] = bn_d["out_val"]
            prev["out_shape"] = bn_d["out_shape"]
            prev["lo"] = min(prev["lo"], bn_d["lo"])
            prev["hi"] = max(prev["hi"], bn_d["hi"])
            uses[bn_d["inputs"][0]] = 0
    if fusion != "none":
        i = 0
        while i < len(drafts):
            d = drafts[i]
            p = None
            if d["layer"]["kind"] in ACTS:
                v = d["inputs"][0]
                cand = producer_of(v)
                kind = drafts[cand]["layer"]["kind"] if cand is not None else None
                accepts = kind is not None and kind not in ("flatten", "softmax") + ACTS
                if (cand is not None and uses[v] == 1 and drafts[cand]["act"] is None
                        and accepts):
                    p = cand
            if p is None:
                i += 1
                continue
            act_d = drafts.pop(i)
            prev = drafts[p]
            prev["act"] = act_d["layer"]["kind"]
            prev["out_val"] = act_d["out_val"]
            prev["out_shape"] = act_d["out_shape"]
            prev["lo"] = min(prev["lo"], act_d["lo"])
            prev["hi"] = max(prev["hi"], act_d["hi"])
            uses[act_d["inputs"][0]] = 0

    # Buffer assignment (LIFO free list; in-place aliasing for act/flatten).
    remaining = list(uses)
    buf_of_val = [None] * (n + 1)
    buf_lens = [math.prod(model["input_shape"])]
    free = []
    buf_of_val[0] = 0
    steps = []
    for d in drafts:
        in_bufs = [buf_of_val[v] for v in d["inputs"]]
        out_len = math.prod(d["out_shape"])
        in_place_capable = d["layer"]["kind"] in ("flatten",) + ACTS
        in_place = (in_place_capable and len(d["inputs"]) == 1
                    and remaining[d["inputs"][0]] == 1)
        if in_place:
            out_buf = in_bufs[0]
        elif free:
            out_buf = free.pop()
        else:
            buf_lens.append(0)
            out_buf = len(buf_lens) - 1
        buf_lens[out_buf] = max(buf_lens[out_buf], out_len)
        buf_of_val[d["out_val"]] = out_buf
        for v, b in zip(d["inputs"], in_bufs):
            remaining[v] -= 1
            if remaining[v] == 0 and b != out_buf:
                free.append(b)
        steps.append({"layer": d["layer"], "inputs": in_bufs, "out": out_buf,
                      "in_shapes": d["in_shapes"], "out_shape": d["out_shape"],
                      "act": d["act"], "lo": d["lo"], "hi": d["hi"]})

    output_buf = buf_of_val[output_val]

    # Blocked lowering metadata + panel-only diet swap.
    for s in steps:
        layer, kind = s["layer"], s["layer"]["kind"]
        s["lower"] = "-"
        s["panel"] = s["table"] = s["full_table"] = 0
        if kernels != "blocked":
            continue
        if kind == "dense":
            s["lower"] = "panel"
            tiles = max(-(-layer["m"] // MR), 1)
            s["panel"] = tiles * layer["n"] * MR * F64B
            if layer["folded"]:
                layer["panel_only"] = True
        elif kind == "conv2d":
            s["lower"] = "im2col"
            s["table"], s["full_table"] = im2col_bytes(layer, s["in_shapes"][0],
                                                       s["out_shape"])
        elif kind == "depthwise_conv2d":
            s["lower"] = "taps"
            s["table"], s["full_table"] = dw_bytes(layer, s["in_shapes"][0],
                                                   s["out_shape"])
        elif kind == "avg_pool2d":
            s["lower"] = "pool"
            oh, ow = s["out_shape"][0], s["out_shape"][1]
            taps = layer["ph"] * layer["pw"]
            # Single row class (windows tile exactly, never padded) + map.
            s["table"] = ow * taps * USIZE + oh * 2 * USIZE
            s["full_table"] = oh * ow * taps * USIZE

    deps = compute_deps(steps, len(buf_lens))
    return {"name": model["name"], "fusion": fusion, "kernels": kernels,
            "input_shape": model["input_shape"], "output_shape": val_shape[output_val],
            "input_buf": 0, "output_buf": output_buf, "buf_lens": buf_lens,
            "steps": steps, "deps": deps}


def im2col_row_classes(kh, stride, pad_top, h, oh):
    """Yield (class, delta, oy, materialize) mirroring gemm::Im2col::build."""
    classes = 0
    interior_ref = None
    out = []
    for oy in range(oh):
        interior = oy * stride >= pad_top and oy * stride + kh <= h + pad_top
        if interior and interior_ref is not None:
            cl, oy_ref = interior_ref
            out.append((cl, oy - oy_ref, oy, False))
            continue
        cl = classes
        classes += 1
        out.append((cl, 0, oy, True))
        if interior:
            interior_ref = (cl, oy)
    return out, classes


def im2col_bytes(layer, in_shape, out_shape):
    kh, kw, cin, _ = layer["k"]
    h, w = in_shape[0], in_shape[1]
    oh, ow = out_shape[0], out_shape[1]
    pad_top, _, _, _ = pad_offsets(h, w, kh, kw, layer["stride"], layer["pad"])
    k = kh * kw * cin
    _, classes = im2col_row_classes(kh, layer["stride"], pad_top, h, oh)
    table = classes * ow * k * USIZE + oh * 2 * USIZE  # rows + row_map
    return table, oh * ow * k * USIZE


def dw_bytes(layer, in_shape, out_shape):
    """gemm::DwTable row-class bytes (spatial taps; same classes as im2col)."""
    kh, kw, _ = layer["k"]
    h, w = in_shape[0], in_shape[1]
    oh, ow = out_shape[0], out_shape[1]
    pad_top, _, _, _ = pad_offsets(h, w, kh, kw, layer["stride"], layer["pad"])
    taps = kh * kw
    _, classes = im2col_row_classes(kh, layer["stride"], pad_top, h, oh)
    table = classes * ow * taps * USIZE + oh * 2 * USIZE  # rows + row_map
    return table, oh * ow * taps * USIZE


def compute_deps(steps, n_bufs):
    last_writer = [None] * n_bufs
    readers = [[] for _ in range(n_bufs)]
    deps = []
    for i, s in enumerate(steps):
        pred = []
        for b in s["inputs"]:
            if last_writer[b] is not None:
                pred.append(last_writer[b])
        if last_writer[s["out"]] is not None:
            pred.append(last_writer[s["out"]])
        pred.extend(readers[s["out"]])
        pred = sorted(set(p for p in pred if p != i))
        for b in s["inputs"]:
            if b != s["out"]:
                readers[b].append(i)
        last_writer[s["out"]] = i
        readers[s["out"]] = []
        deps.append(pred)
    return deps


# --------------------------------------------------------------------------
# Memory accounting + renderer (mirrors plan::ir)
# --------------------------------------------------------------------------


def step_memory(s):
    layer, kind = s["layer"], s["layer"]["kind"]
    weight = shared = 0
    if kind == "dense":
        wb = layer["m"] * layer["n"] * F64B
        if layer.get("panel_only"):
            pass
        elif layer["folded"]:
            weight += wb
        else:
            shared += wb
        weight += layer["m"] * F64B  # bias
    elif kind in ("conv2d", "depthwise_conv2d"):
        kb = math.prod(layer["k"]) * F64B
        if layer["folded"]:
            weight += kb
        else:
            shared += kb
        weight += layer["k"][3 if kind == "conv2d" else 2] * F64B  # bias
    elif kind == "batch_norm":
        weight += 4 * layer["c"] * F64B
    if kind == "dense":
        baseline = (layer["m"] * layer["n"] + layer["m"]) * F64B + s["panel"]
    elif kind in ("conv2d", "depthwise_conv2d"):
        cc = layer["k"][3 if kind == "conv2d" else 2]
        baseline = (math.prod(layer["k"]) + cc) * F64B + s["full_table"]
    elif kind == "avg_pool2d":
        baseline = s["full_table"]
    else:
        baseline = weight + s["table"]
    return weight, shared, s["panel"], s["table"], baseline


def shape_tok(shape):
    return "x".join(str(d) for d in shape)


def list_tok(items):
    items = list(items)
    return ",".join(items) if items else "-"


def step_tokens(s):
    layer, kind = s["layer"], s["layer"]["kind"]
    toks = []
    if kind == "dense":
        toks.append(f"w={layer['m']}x{layer['n']}")
        wsrc = ("panel" if layer.get("panel_only")
                else "folded" if layer["folded"] else "shared")
        toks.append(f"wsrc={wsrc}")
        toks.append(f"params={layer['m'] * layer['n'] + layer['m']}")
    elif kind in ("conv2d", "depthwise_conv2d"):
        toks.append(f"k={shape_tok(layer['k'])}")
        toks.append(f"stride={layer['stride']}")
        toks.append(f"pad={layer['pad']}")
        toks.append(f"wsrc={'folded' if layer['folded'] else 'shared'}")
        cc = layer["k"][3 if kind == "conv2d" else 2]
        toks.append(f"params={math.prod(layer['k']) + cc}")
    elif kind in ("max_pool2d", "avg_pool2d"):
        toks.append(f"window={layer['ph']}x{layer['pw']}")
    elif kind == "batch_norm":
        toks.append(f"c={layer['c']}")
        toks.append(f"eps={layer['eps']}")
        toks.append(f"params={4 * layer['c']}")
    elif kind == "concat":
        rows = math.prod(s["out_shape"][:-1])
        widths = ",".join(str(sh[-1]) for sh in s["in_shapes"])
        toks.append(f"rows={rows}")
        toks.append(f"widths={widths}")
    return toks


def render(plan):
    lines = [f"plan {plan['name']}", f"fusion {plan['fusion']}",
             f"kernels {plan['kernels']}",
             f"input b{plan['input_buf']} {shape_tok(plan['input_shape'])}",
             f"output b{plan['output_buf']} {shape_tok(plan['output_shape'])}", ""]
    nbufs = len(plan["buf_lens"])
    writers = [[] for _ in range(nbufs)]
    readers = [[] for _ in range(nbufs)]
    for i, s in enumerate(plan["steps"]):
        for b in s["inputs"]:
            if not readers[b] or readers[b][-1] != i:
                readers[b].append(i)
        writers[s["out"]].append(i)
    lines.append(f"buffers {nbufs}")
    for b in range(nbufs):
        lines.append(f"b{b} len={plan['buf_lens'][b]}"
                     f" writers={list_tok(f's{i}' for i in writers[b])}"
                     f" readers={list_tok(f's{i}' for i in readers[b])}")
    lines.append("")
    lines.append(f"steps {len(plan['steps'])}")
    for i, s in enumerate(plan["steps"]):
        act = s["act"] if s["act"] else "-"
        toks = [f"s{i}", s["layer"]["kind"],
                f"in={list_tok(f'b{b}' for b in s['inputs'])}", f"out=b{s['out']}",
                f"in_shapes={list_tok(shape_tok(sh) for sh in s['in_shapes'])}",
                f"out_shape={shape_tok(s['out_shape'])}", f"act={act}",
                f"layers={s['lo']}..{s['hi']}",
                f"deps={list_tok(f's{d}' for d in plan['deps'][i])}",
                f"lower={s['lower']}"] + step_tokens(s)
        lines.append(" ".join(toks))
    lines.append("")
    lines.append("memory")
    tot = [0] * 5
    for i, s in enumerate(plan["steps"]):
        w, sh, p, t, base = step_memory(s)
        for j, v in enumerate((w, sh, p, t, base)):
            tot[j] += v
        lines.append(f"s{i} {s['layer']['kind']} weights={w} shared={sh} panel={p}"
                     f" table={t} resident={w + p + t} baseline={base}")
    lines.append(f"total weights={tot[0]} shared={tot[1]} panel={tot[2]} table={tot[3]}"
                 f" resident={tot[0] + tot[2] + tot[3]} baseline={tot[4]}")
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# Oracle checks
# --------------------------------------------------------------------------


def full_im2col_row(oy, ox, kh, kw, cin, stride, pad_top, pad_left, h, w):
    """One output pixel's tap offsets in the old full-table layout."""
    row = [PAD] * (kh * kw * cin)
    for ky in range(kh):
        iy = oy * stride + ky - pad_top
        if iy < 0 or iy >= h:
            continue
        for kx in range(kw):
            ix = ox * stride + kx - pad_left
            if ix < 0 or ix >= w:
                continue
            for ci in range(cin):
                row[(ky * kw + kx) * cin + ci] = (iy * w + ix) * cin + ci
    return row


def check_im2col_equivalence(kh, kw, cin, cout, h, w, stride, pad):
    """Per-row-class table + delta must reproduce the full table exactly."""
    pad_top, pad_left, oh, ow = pad_offsets(h, w, kh, kw, stride, pad)
    rows, _ = im2col_row_classes(kh, stride, pad_top, h, oh)
    # Materialized class tables (class -> per-ox rows), built like Rust.
    class_rows = {}
    for cl, _, oy, materialize in rows:
        if materialize:
            class_rows[cl] = [full_im2col_row(oy, ox, kh, kw, cin, stride,
                                              pad_top, pad_left, h, w)
                              for ox in range(ow)]
    for cl, doy, oy, _ in rows:
        delta = doy * stride * w * cin
        for ox in range(ow):
            want = full_im2col_row(oy, ox, kh, kw, cin, stride, pad_top, pad_left, h, w)
            got = [PAD if e is PAD else e + delta for e in class_rows[cl][ox]]
            assert got == want, (kh, kw, cin, h, w, stride, pad, oy, ox)


def full_dw_row(oy, ox, kh, kw, stride, pad_top, pad_left, h, w):
    """One output pixel's spatial tap offsets in the old full-table layout."""
    row = [PAD] * (kh * kw)
    for ky in range(kh):
        iy = oy * stride + ky - pad_top
        if iy < 0 or iy >= h:
            continue
        for kx in range(kw):
            ix = ox * stride + kx - pad_left
            if ix < 0 or ix >= w:
                continue
            row[ky * kw + kx] = iy * w + ix
    return row


def check_dw_equivalence(kh, kw, h, w, stride, pad):
    """gemm::DwTable row classes + delta must reproduce the full table."""
    pad_top, pad_left, oh, ow = pad_offsets(h, w, kh, kw, stride, pad)
    rows, _ = im2col_row_classes(kh, stride, pad_top, h, oh)
    class_rows = {}
    for cl, _, oy, materialize in rows:
        if materialize:
            class_rows[cl] = [full_dw_row(oy, ox, kh, kw, stride, pad_top,
                                          pad_left, h, w) for ox in range(ow)]
    for cl, doy, oy, _ in rows:
        delta = doy * stride * w
        for ox in range(ow):
            want = full_dw_row(oy, ox, kh, kw, stride, pad_top, pad_left, h, w)
            got = [PAD if e is PAD else e + delta for e in class_rows[cl][ox]]
            assert got == want, (kh, kw, h, w, stride, pad, oy, ox)


def check_pool_equivalence(ph, pw, h, w):
    """gemm::PoolTable's single row-0 class + delta must cover every row."""
    oh, ow = h // ph, w // pw
    taps = ph * pw
    rows = [ky * w + ox * pw + kx for ox in range(ow)
            for ky in range(ph) for kx in range(pw)]
    for oy in range(oh):
        delta = oy * ph * w
        for ox in range(ow):
            want = [(oy * ph + ky) * w + (ox * pw + kx)
                    for ky in range(ph) for kx in range(pw)]
            got = [rows[ox * taps + t] + delta for t in range(taps)]
            assert got == want, (ph, pw, h, w, oy, ox)


def self_check():
    # Per-row im2col equivalence: gemm test geometries + zoo convs.
    geoms = [(3, 3, 3, 5, 5, 7, 1, "same"), (2, 2, 3, 4, 7, 5, 2, "valid"),
             (1, 1, 4, 1, 6, 6, 1, "same"), (3, 3, 1, 4, 4, 4, 2, "same"),
             (3, 3, 1, 4, 6, 6, 1, "same"), (3, 3, 4, 4, 6, 6, 1, "same"),
             (1, 1, 4, 2, 6, 6, 1, "same"), (3, 3, 4, 2, 6, 6, 1, "same"),
             (3, 3, 2, 3, 9, 9, 3, "same"), (5, 3, 2, 2, 11, 8, 2, "valid")]
    for kh, kw, cin, cout, h, w, stride, pad in geoms:
        check_im2col_equivalence(kh, kw, cin, cout, h, w, stride, pad)

    # Same factoring for depthwise tap tables and pool window tables.
    dw_geoms = [(3, 3, 6, 6, 1, "same"), (3, 3, 5, 5, 1, "same"),
                (2, 2, 6, 4, 2, "valid"), (3, 2, 7, 5, 2, "same"),
                (5, 3, 11, 8, 2, "valid"), (1, 1, 6, 6, 1, "same")]
    for kh, kw, h, w, stride, pad in dw_geoms:
        check_dw_equivalence(kh, kw, h, w, stride, pad)
    for ph, pw, h, w in [(2, 2, 6, 6), (3, 3, 9, 9), (2, 3, 4, 6), (1, 1, 5, 5)]:
        check_pool_equivalence(ph, pw, h, w)

    # Memory-diet floor on the cached blocked residual_cnn reference plan.
    plan = compile_plan(residual_cnn(), "full", "blocked")
    tot = [0] * 5
    for s in plan["steps"]:
        for j, v in enumerate(step_memory(s)):
            tot[j] += v
    weights, shared, panel, table, baseline = tot
    resident = weights + panel + table
    assert (weights, shared, panel, table) == (424, 3232, 2304, 12240), tot
    assert resident == 14968 and baseline == 30440, (resident, baseline)
    assert baseline >= 2 * resident

    # Row-class shrink pins for depthwise/pool tables (avgpool_cnn carries
    # the only zoo avg_pool2d; its dw step shares the conv's row classes).
    plan = compile_plan(avgpool_cnn(), "full", "blocked")
    tot = [0] * 5
    for s in plan["steps"]:
        for j, v in enumerate(step_memory(s)):
            tot[j] += v
    weights, shared, panel, table, baseline = tot
    resident = weights + panel + table
    assert table == 2928 and resident == 5624, tot
    assert baseline == 9896, baseline

    # Determinism: two compiles render byte-identically.
    a = render(compile_plan(residual_cnn(), "full", "blocked"))
    b = render(compile_plan(residual_cnn(), "full", "blocked"))
    assert a == b


def main():
    self_check()
    out_dir = os.path.dirname(os.path.abspath(__file__))
    count = 0
    for build in ZOO:
        for fmt, fusion in [("f64", "full"), ("emu-k12", "none")]:
            for kernels in ["blocked", "scalar"]:
                model = build()
                text = render(compile_plan(model, fusion, kernels))
                name = f"{model['name']}__{fmt}__{kernels}.plan"
                with open(os.path.join(out_dir, name), "w") as f:
                    f.write(text)
                count += 1
    print(f"self-check OK; wrote {count} goldens to {out_dir}")


if __name__ == "__main__":
    main()
