//! Cross-module integration tests that need no AOT artifacts: the full
//! analysis pipeline (model zoo -> api::Session -> engine -> CAA ->
//! margins -> report), the service API's caching / streaming / JSON
//! contract, plus the deprecated shims' equivalence.

use rigor::api::{AnalysisOutcome, AnalysisRequest, ExecMode, Session, SCHEMA_VERSION};
use rigor::caa::{Caa, Ctx};
use rigor::data::{synthetic, Dataset};
use rigor::model::{model_from_json, model_to_json, zoo, Model};
use rigor::quant::EmulatedFp;
use rigor::report::{table1_console, table1_markdown, TableRow};
use rigor::tensor::{EmuCtx, Tensor};
use rigor::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn digits_like_dataset(n: usize) -> Dataset {
    let mut rng = Rng::new(3);
    synthetic::digits(&mut rng, 8, n.div_ceil(10), 0.05)
}

#[test]
fn full_pipeline_zoo_mlp_to_table() {
    // Build a digits-like dataset + mlp, analyze through the service API,
    // and render a Table-I row.
    let mut rng = Rng::new(10);
    let data = synthetic::digits(&mut rng, 8, 2, 0.05);
    let model = zoo::scaled_mlp(1, 64, 32, 10);
    let session = Session::new();
    let req = AnalysisRequest::builder()
        .model(model)
        .data(data)
        .exact_inputs(true) // integer pixels
        .build()
        .unwrap();
    let out = session.run(&req).unwrap();
    let a = &out.analysis;
    assert_eq!(a.per_class.len(), 10);
    assert!(a.max_abs_u.is_finite());
    assert!(a.required_k.is_some());

    let row = out.table_row();
    let md = table1_markdown(&[row.clone()], 0.60, -7);
    assert!(md.contains(&a.model_name));
    let console = table1_console(&[row], 0.60);
    assert!(console.contains("required k"));
}

#[test]
fn pooled_equals_serial_on_real_sized_fanout() {
    let data = digits_like_dataset(30);
    let model = zoo::scaled_mlp(2, 64, 48, 10);
    let session = Session::builder().workers(4).build();
    let serial = AnalysisRequest::builder()
        .model(model.clone())
        .data(data.clone())
        .build()
        .unwrap();
    let pooled = AnalysisRequest::builder()
        .model(model)
        .data(data)
        .mode(ExecMode::Pooled { workers: 0 })
        .build()
        .unwrap();
    let seq = session.run(&serial).unwrap().analysis;
    let par = session.run(&pooled).unwrap().analysis;
    assert_eq!(seq.max_abs_u, par.max_abs_u);
    assert_eq!(seq.max_rel_u, par.max_rel_u);
    assert_eq!(seq.required_k, par.required_k);
    assert_eq!(session.pool().metrics().submitted, 10);
    // The worker-side completion counter may lag the batch's own result
    // barrier by a few instructions; give it a moment.
    for _ in 0..100 {
        if session.pool().metrics().completed == 10 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(session.pool().metrics().completed, 10);
}

#[test]
fn progress_callback_streams_from_pooled_workers() {
    let data = digits_like_dataset(30);
    let model = zoo::scaled_mlp(2, 64, 48, 10);
    let session = Session::builder().workers(4).build();
    let seen = Arc::new(AtomicUsize::new(0));
    let seen2 = Arc::clone(&seen);
    let req = AnalysisRequest::builder()
        .model(model)
        .data(data)
        .mode(ExecMode::Pooled { workers: 0 })
        .on_class(move |c| {
            assert!(c.max_abs_u >= 0.0);
            seen2.fetch_add(1, Ordering::SeqCst);
        })
        .build()
        .unwrap();
    let out = session.run(&req).unwrap();
    assert_eq!(seen.load(Ordering::SeqCst), out.analysis.per_class.len());
}

#[test]
fn model_json_roundtrip_through_files_preserves_analysis_and_caches() {
    let model = zoo::tiny_cnn(5);
    let dir = std::env::temp_dir().join("rigor_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cnn.json");
    model.save(&path).unwrap();

    let mut rng = Rng::new(8);
    let data = synthetic::color_blobs(&mut rng, 6, 3, 1);
    // tiny_cnn takes [6,6,1]; adapt: grayscale one channel of blobs.
    let inputs: Vec<Vec<f64>> = data
        .inputs
        .iter()
        .map(|img| img.iter().step_by(3).cloned().collect())
        .collect();
    let ds = Dataset { input_shape: vec![6, 6, 1], inputs, labels: data.labels.clone() };

    let session = Session::new();
    let inline = AnalysisRequest::builder()
        .model(model)
        .data(ds.clone())
        .build()
        .unwrap();
    let from_file = AnalysisRequest::builder()
        .model_path(&path)
        .data(ds)
        .build()
        .unwrap();
    let a1 = session.run(&inline).unwrap().analysis;
    let a2 = session.run(&from_file).unwrap().analysis;
    assert_eq!(a1.max_abs_u, a2.max_abs_u, "JSON round-trip must not perturb analysis");

    // A repeated file-backed request is served from the model cache.
    let a3 = session.run(&from_file).unwrap().analysis;
    assert_eq!(a2.max_abs_u, a3.max_abs_u);
    let stats = session.cache_stats();
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.hits, 1);
}

#[test]
fn outcome_json_is_versioned_and_roundtrips() {
    let session = Session::new();
    let req = AnalysisRequest::builder()
        .model(zoo::tiny_pendulum(7))
        .input_box()
        .input_radius(6.0)
        .exact_inputs(true)
        .build()
        .unwrap();
    let out = session.run(&req).unwrap();
    let text = out.to_json_string();
    let v = rigor::json::parse(&text).expect("outcome JSON must parse");
    assert_eq!(
        v.get("schema_version").and_then(rigor::json::Value::as_usize),
        Some(SCHEMA_VERSION as usize)
    );
    let back = AnalysisOutcome::from_json(&v).unwrap();
    assert_eq!(back.analysis.model_name, out.analysis.model_name);
    assert_eq!(back.analysis.max_abs_u, out.analysis.max_abs_u);
    assert_eq!(back.analysis.required_k, out.analysis.required_k);
    assert_eq!(back.analysis.per_class.len(), out.analysis.per_class.len());
}

#[test]
#[allow(deprecated)]
fn deprecated_shims_match_session_results() {
    // The migration contract: old callers still get the exact numbers the
    // new front door serves.
    let data = digits_like_dataset(20);
    let model = zoo::scaled_mlp(9, 64, 32, 10);
    let session = Session::builder().workers(2).build();
    let req = AnalysisRequest::builder()
        .model(model.clone())
        .data(data.clone())
        .build()
        .unwrap();
    let via_api = session.run(&req).unwrap().analysis;
    let cfg = req.analysis_config();
    let via_shim = rigor::analysis::analyze_model(&model, &data, &cfg).unwrap();
    assert_eq!(via_api.max_abs_u, via_shim.max_abs_u);
    assert_eq!(via_api.required_k, via_shim.required_k);
    let pool = rigor::coordinator::Pool::new(2, 8);
    let via_par_shim =
        rigor::coordinator::analyze_model_parallel(&model, &data, &cfg, &pool).unwrap();
    assert_eq!(via_api.max_abs_u, via_par_shim.max_abs_u);
}

#[test]
fn emulated_precision_argmax_agreement_rises_with_k() {
    // The motivating observation (E-acc-vs-k) on the engine-only stack:
    // classification agreement with the f64 reference improves with k.
    let model = zoo::scaled_mlp(7, 64, 48, 10);
    let data = digits_like_dataset(40);
    let mut agree = Vec::new();
    for k in [3u32, 6, 10, 16] {
        let ec = EmuCtx { k };
        let mut same = 0;
        for input in &data.inputs {
            let xr = Tensor::new(model.input_shape.clone(), input.clone());
            let yr = model.forward::<f64>(&(), xr).unwrap();
            let xe = Tensor::new(
                model.input_shape.clone(),
                input.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
            );
            let ye = model.forward::<EmulatedFp>(&ec, xe).unwrap();
            let am_r = argmax(yr.data());
            let am_e = argmax_emu(ye.data());
            if am_r == am_e {
                same += 1;
            }
        }
        agree.push(same);
    }
    assert!(
        agree.last().unwrap() >= agree.first().unwrap(),
        "agreement must not degrade with precision: {agree:?}"
    );
    assert_eq!(
        *agree.last().unwrap(),
        data.inputs.len(),
        "k=16 must match f64 argmax everywhere: {agree:?}"
    );
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn argmax_emu(xs: &[EmulatedFp]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.v.partial_cmp(&b.1.v).unwrap())
        .unwrap()
        .0
}

#[test]
fn required_k_guarantee_holds_empirically() {
    // If the analysis says precision k is safe for p* and the top-1 trace
    // confidence is >= p*, then the emulated-k run must predict the same
    // class. (The *contract* of the paper's §IV.)
    let model = zoo::scaled_mlp(21, 64, 48, 10);
    let data = digits_like_dataset(30);
    let session = Session::new();
    let req = AnalysisRequest::builder()
        .model(model.clone())
        .data(data.clone())
        .exact_inputs(true)
        .p_star(0.60)
        .build()
        .unwrap();
    let out = session.run(&req).unwrap();
    let Some(k) = out.required_k() else {
        return; // no guarantee possible for this random net — vacuous
    };
    let k = k.min(24);
    let ec = EmuCtx { k };
    for input in &data.inputs {
        let xr = Tensor::new(model.input_shape.clone(), input.clone());
        let yr = model.forward::<f64>(&(), xr).unwrap();
        let top = argmax(yr.data());
        if yr.data()[top] < req.p_star() {
            continue; // contract only covers confident predictions
        }
        let xe = Tensor::new(
            model.input_shape.clone(),
            input.iter().map(|&v| EmulatedFp::new(v, k)).collect(),
        );
        let ye = model.forward::<EmulatedFp>(&ec, xe).unwrap();
        assert_eq!(
            argmax_emu(ye.data()),
            top,
            "k={k} flipped a confident prediction — the §IV guarantee failed"
        );
    }
}

#[test]
fn softmax_theory_vs_caa_consistency() {
    // The 11/2 softmax bound (eq. 11) must also be visible in CAA output:
    // feeding logits with absolute bound δ̄ through the CAA softmax yields
    // relative bounds <= ~5.5 δ̄ + rounding terms.
    let ctx = Ctx::new();
    let delta = 2.0; // logits carry 2u absolute error
    let logits: Vec<Caa> = [1.0f64, 0.2, -0.7, 2.2]
        .iter()
        .map(|&v| {
            Caa::from_parts(
                &ctx,
                v,
                rigor::interval::Interval::point(v),
                rigor::interval::Interval::new(v - delta * ctx.u_max, v + delta * ctx.u_max),
                delta,
                f64::INFINITY,
            )
        })
        .collect();
    let out = rigor::layers::softmax_vec(&ctx, &logits);
    for o in &out {
        assert!(o.rel_bound().is_finite());
        // eq. (11) scale: 5.5 * δ̄ = 11; allow rounding-term headroom.
        assert!(
            o.rel_bound() <= 5.5 * delta + 8.0,
            "rel bound {} far above the 11/2 law",
            o.rel_bound()
        );
    }
    // Empirical cross-check of the law itself.
    let worst = rigor::analysis::softmax_theory::max_amplification(3, 10, 1e-4, 100);
    assert!(worst <= 5.5);
}

#[test]
fn margins_and_report_end_to_end() {
    let m = rigor::analysis::Margins::new(0.6).unwrap();
    assert!(m.abs_margin() > 0.0 && m.rel_margin() > 0.0);
    // Rendering with a missing bound (pendulum-style).
    let rows = vec![TableRow {
        name: "pendulum".into(),
        max_abs_u: 1.7,
        max_rel_u: f64::INFINITY,
        time_per_class: std::time::Duration::from_millis(100),
        required_k: None,
    }];
    let md = table1_markdown(&rows, 0.6, -7);
    assert!(md.contains("| pendulum | 1.7u | - |"));
}

#[test]
fn model_to_json_value_is_parseable_text() {
    let m = zoo::tiny_pendulum(9);
    let text = rigor::json::to_string_pretty(&model_to_json(&m));
    let back = model_from_json(&rigor::json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.name, "tiny_pendulum");
}

// ---------------------------------------------------------------------------
// Mixed precision (paper §VI future work, served through the Session API)
// ---------------------------------------------------------------------------

#[test]
fn mixed_tuning_on_trained_pendulum() {
    use rigor::analysis::mixed;
    let model_path = rigor::runtime::default_dir().join("models/pendulum.json");
    let (model, data) = if model_path.exists() {
        (
            Model::load(&model_path).unwrap(),
            Dataset::load(&rigor::runtime::default_dir().join("data/pendulum_eval.json")).unwrap(),
        )
    } else {
        (zoo::tiny_pendulum(3), synthetic::pendulum_grid(3))
    };
    let session = Session::new();
    let req = AnalysisRequest::builder()
        .model(model.clone())
        .data(data.clone())
        .p_star(0.75)
        .exact_inputs(true)
        .build()
        .unwrap();
    let Some((k0, _)) = session.certify_min_precision(&req, 6..=30).unwrap() else {
        return; // cannot certify this net at all — vacuous for random nets
    };
    let tuned = session.tune_mixed(&req, k0, 4).unwrap();
    assert!(tuned.certified);
    assert_eq!(tuned.ks.len(), model.layers.len());
    assert!(tuned.ks.iter().all(|&k| k <= k0));

    // Witness: the emulated mixed execution stays within the mixed bounds.
    let cfg = req.analysis_config();
    for sample in data.inputs.iter().take(5) {
        let bounds = mixed::analyze_sample_mixed(&model, &cfg, &tuned.ks, sample).unwrap();
        let emu = mixed::forward_mixed_emulated(&model, &tuned.ks, sample).unwrap();
        let reference = model
            .forward::<f64>(&(), Tensor::new(model.input_shape.clone(), sample.clone()))
            .unwrap();
        let u_out = rigor::quant::unit_roundoff(*tuned.ks.last().unwrap());
        for i in 0..emu.len() {
            let err = (emu[i] - reference.data()[i]).abs();
            let bound = bounds[i].abs_bound() * u_out;
            assert!(err <= bound * (1.0 + 1e-9) + 1e-12, "mixed bound violated");
        }
    }
}

#[test]
fn cli_app_parses_all_commands() {
    // The CLI is part of the public surface; exercise its parser against
    // every documented command line from the README.
    use rigor::cli::{App, CmdSpec, OptSpec};
    let app = App {
        name: "t",
        about: "t",
        commands: vec![CmdSpec {
            name: "analyze",
            help: "",
            opts: vec![
                OptSpec { name: "model", help: "", default: Some("m".into()) },
                OptSpec { name: "exact-inputs", help: "", default: None },
            ],
        }],
    };
    let p = app
        .parse(&["analyze".into(), "--model=x.json".into(), "--exact-inputs".into()])
        .unwrap();
    assert_eq!(p.get("model"), Some("x.json"));
    assert!(p.flag("exact-inputs"));
}

#[test]
fn layer_error_paths_report_context() {
    // Wrong-shape inputs produce contextual errors, not panics.
    let m = zoo::tiny_cnn(1);
    let bad = Tensor::filled(vec![5, 5, 1], 0.5f64);
    let err = m.forward::<f64>(&(), bad).unwrap_err().to_string();
    assert!(err.contains("expects input"), "{err}");

    let d = Layer::Dense {
        w: Arc::new(rigor::tensor::Tensor::new(vec![2, 3], vec![0.0; 6])),
        b: vec![0.0; 2],
    };
    assert!(d.output_shape(&[4]).is_err());
}

use rigor::layers::Layer;

#[test]
fn caa_analysis_deterministic_across_runs() {
    // The whole pipeline is deterministic: same model + sample => exact
    // same bounds (needed for reproducible EXPERIMENTS.md numbers).
    let m = zoo::tiny_cnn(77);
    let n: usize = m.input_shape.iter().product();
    let sample: Vec<f64> = (0..n).map(|i| (i % 5) as f64 / 5.0).collect();
    let cfg = AnalysisRequest::builder().build_config().unwrap();
    let a = rigor::analysis::analyze_class(&m, &cfg, 0, &sample).unwrap();
    let b = rigor::analysis::analyze_class(&m, &cfg, 0, &sample).unwrap();
    assert_eq!(a.max_abs_u, b.max_abs_u);
    assert_eq!(a.max_rel_u, b.max_rel_u);
    assert_eq!(a.predicted, b.predicted);
}

#[test]
fn report_handles_all_bound_shapes() {
    use rigor::report::fmt_bound_u;
    assert_eq!(fmt_bound_u(f64::INFINITY), "-");
    assert_eq!(fmt_bound_u(0.0), "0u");
    assert!(fmt_bound_u(1e9).ends_with('u'));
}
