//! Chaos suite for the fault-containment layer.
//!
//! The invariant under test, at every fault mix the harness can produce:
//! **every admitted ticket resolves, exactly once, with a typed outcome** —
//! no lost tickets, no deadlocks, no cross-queue contamination — and with
//! injection disarmed the stack serves bit-identical outputs again
//! (nothing is left poisoned by a contained fault).
//!
//! The [`rigor::faultinject`] harness is process-global, so every test
//! holds a shared lock while armed ([`ChaosGuard`]); the guard also
//! disarms on drop (including unwinds) and, when `RIGOR_CHAOS_TRACE_OUT`
//! is set (CI), exports the chrome trace on failure so chaos failures are
//! debuggable from the artifact alone.

use rigor::coordinator::Pool;
use rigor::faultinject::{self, ChaosPlan, FaultKind, SITES};
use rigor::fleet::{AdmitError, Fleet, FleetPolicy};
use rigor::model::zoo;
use rigor::plan::{Arena, Plan, ServeFormat};
use rigor::serve::{BatchPolicy, MicroBatcher, ServeError, Ticket};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// The one lock serializing armed sections across this binary's tests.
fn chaos_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Holds the chaos lock with a plan armed; disarms on drop (even on
/// unwind) and exports the chrome trace to `RIGOR_CHAOS_TRACE_OUT` when a
/// test is failing.
struct ChaosGuard {
    _lock: MutexGuard<'static, ()>,
}

impl ChaosGuard {
    fn arm(plan: ChaosPlan) -> ChaosGuard {
        let lock = chaos_lock().lock().unwrap_or_else(|e| e.into_inner());
        faultinject::arm(plan);
        ChaosGuard { _lock: lock }
    }
}

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        faultinject::disarm();
        if std::thread::panicking() {
            if let Some(path) = std::env::var_os("RIGOR_CHAOS_TRACE_OUT") {
                let _ = std::fs::write(path, rigor::obs::TraceSink::export());
            }
        }
    }
}

fn sample(n: usize, i: usize) -> Vec<f64> {
    (0..n).map(|j| ((i * n + j) % 13) as f64 / 13.0).collect()
}

/// Reference bits for one sample through a plan (the serial oracle).
fn reference_bits(plan: &Plan, s: &[f64], arena: &mut Arena<f64>) -> Vec<u64> {
    plan.execute::<f64>(&(), s, arena).unwrap().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn panic_storm_resolves_every_ticket_and_the_batcher_recovers() {
    let _g = ChaosGuard::arm(ChaosPlan { seed: 0xA1, panic_in_256: 255, ..ChaosPlan::default() });
    let model = zoo::tiny_mlp(11);
    let plan = Arc::new(Plan::for_reference(&model).unwrap());
    let pool = Arc::new(Pool::new(2, 8));
    let batcher = MicroBatcher::new(
        Arc::clone(&plan),
        pool,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        },
    );
    let mut panicked = 0usize;
    for i in 0..12 {
        let t = batcher.submit(sample(8, i)).unwrap();
        // Every ticket resolves with a typed outcome — a panicking drive
        // never leaves a waiter blocked.
        match t.wait_typed() {
            Ok(row) => assert_eq!(row.len(), 3),
            Err(ServeError::DrivePanicked { detail }) => {
                panicked += 1;
                assert!(detail.contains("injected fault"), "unexpected cause: {detail}");
            }
            Err(e) => panic!("unexpected outcome: {e}"),
        }
    }
    assert!(panicked >= 1, "a 255/256 panic plan must hit");
    assert!(batcher.metrics().drive_faults >= panicked);

    // Disarm: the same batcher (same flusher thread, same pool, same
    // worker arenas that were unwound through) must serve bit-identical
    // outputs — the contained panics poisoned nothing.
    faultinject::disarm();
    let mut arena: Arena<f64> = Arena::new();
    for i in 0..6 {
        let got = batcher.submit(sample(8, i)).unwrap().wait_typed().unwrap();
        let want = reference_bits(&plan, &sample(8, i), &mut arena);
        let got_bits: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want, "post-recovery request {i}");
    }
}

#[test]
fn injected_delays_trip_ticket_deadlines() {
    let _g = ChaosGuard::arm(ChaosPlan {
        seed: 0xD1,
        delay_in_256: 255,
        delay_ms: 30,
        ..ChaosPlan::default()
    });
    let model = zoo::tiny_mlp(11);
    let plan = Arc::new(Plan::for_reference(&model).unwrap());
    // One worker, one queue slot: delayed drives back later batches up
    // past the 5 ms deadline.
    let pool = Arc::new(Pool::new(1, 1));
    let batcher = MicroBatcher::new(
        Arc::clone(&plan),
        pool,
        BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            default_deadline: Some(Duration::from_millis(5)),
            ..BatchPolicy::default()
        },
    );
    let tickets: Vec<Ticket> = (0..6).map(|i| batcher.submit(sample(8, i)).unwrap()).collect();
    let mut expired = 0usize;
    for t in tickets {
        match t.wait_typed() {
            Ok(row) => assert_eq!(row.len(), 3),
            Err(ServeError::DeadlineExceeded { waited_ms }) => {
                expired += 1;
                assert!(waited_ms >= 5, "expired before its deadline: {waited_ms} ms");
            }
            Err(e) => panic!("unexpected outcome: {e}"),
        }
    }
    assert!(expired >= 1, "30 ms stalls behind a 1-wide pool must expire 5 ms tickets");
    assert_eq!(batcher.metrics().deadline_missed, expired);
}

#[test]
fn nan_injection_quarantines_the_queue_and_recovery_paths_clear_it() {
    let _g = ChaosGuard::arm(ChaosPlan { seed: 0xF1, nan_in_256: 255, ..ChaosPlan::default() });
    let pool = Arc::new(Pool::new(2, 8));
    let fleet = Fleet::new(
        Arc::clone(&pool),
        FleetPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            fault_budget: 2,
            degrade_after: 1000, // isolate the quarantine path
            ..FleetPolicy::default()
        },
    );
    fleet.deploy("m", &zoo::tiny_mlp(62)).unwrap();

    // Drive the f64 queue into quarantine: each poisoned drive charges the
    // fault budget, and admission must start rejecting with the typed
    // error once it is exhausted.
    let mut quarantined = false;
    for i in 0..40 {
        match fleet.submit("m", ServeFormat::F64, sample(8, i)) {
            Ok(t) => match t.wait_typed() {
                Ok(row) => assert_eq!(row.len(), 3),
                Err(ServeError::NonFiniteOutput { .. }) => {}
                Err(e) => panic!("unexpected outcome: {e}"),
            },
            Err(AdmitError::Quarantined { model, format }) => {
                assert_eq!(model, "m");
                assert_eq!(format, ServeFormat::F64);
                quarantined = true;
                break;
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(quarantined, "a 2-fault budget under an always-NaN plan must trip");
    let snap = fleet.snapshot();
    assert_eq!(snap.quarantined, 1);
    assert!(snap.queues.iter().any(|q| q.quarantined && q.faults >= 2));

    // No cross-queue contamination: the same model's emulated lane has its
    // own ledger and still admits.
    let t = fleet.submit("m", ServeFormat::Emulated { k: 12 }, sample(8, 0)).unwrap();
    assert!(t.wait_typed().map(|row| row.len() == 3).unwrap_or(true));

    // Recovery path 1: manual reinstate lifts the quarantine.
    assert!(fleet.reinstate("m", ServeFormat::F64));
    faultinject::disarm();
    let t = fleet.submit("m", ServeFormat::F64, sample(8, 1)).unwrap();
    assert_eq!(t.wait_typed().unwrap().len(), 3);

    // Recovery path 2: re-poison to quarantine again, then a hot swap
    // clears every queue of the model.
    faultinject::arm(ChaosPlan { seed: 0xF2, nan_in_256: 255, ..ChaosPlan::default() });
    let mut requarantined = false;
    for i in 0..40 {
        match fleet.submit("m", ServeFormat::F64, sample(8, i)) {
            Ok(t) => drop(t.wait_typed()),
            Err(AdmitError::Quarantined { .. }) => {
                requarantined = true;
                break;
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(requarantined);
    faultinject::disarm();
    fleet.deploy("m", &zoo::tiny_mlp(63)).unwrap();
    assert_eq!(fleet.snapshot().quarantined, 0, "hot swap clears the quarantine");
    let t = fleet.submit("m", ServeFormat::F64, sample(8, 2)).unwrap();
    assert_eq!(t.wait_typed().unwrap().len(), 3);
    fleet.shutdown();
}

#[test]
fn repeated_faults_degrade_the_batcher_which_still_serves_correct_bits() {
    let _g = ChaosGuard::arm(ChaosPlan { seed: 0xDE, panic_in_256: 255, ..ChaosPlan::default() });
    let model = zoo::tiny_mlp(11);
    let plan = Arc::new(Plan::for_reference(&model).unwrap());
    let pool = Arc::new(Pool::new(2, 8));
    let batcher = MicroBatcher::new(
        Arc::clone(&plan),
        pool,
        BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..BatchPolicy::default()
        },
    );
    // Each submit is one drive; after enough consecutive faults the
    // batcher must fall back to the scalar/serial escape hatch.
    for i in 0..40 {
        if batcher.degraded() {
            break;
        }
        drop(batcher.submit(sample(8, i)).unwrap().wait_typed());
    }
    assert!(batcher.degraded(), "a panic storm must trip degraded mode");
    assert!(batcher.metrics().drive_faults >= 3);

    // Degraded serving is an escape hatch, not a downgrade in correctness:
    // disarmed, the scalar/serial path serves the reference bits.
    faultinject::disarm();
    let mut arena: Arena<f64> = Arena::new();
    for i in 0..4 {
        let got = batcher.submit(sample(8, i)).unwrap().wait_typed().unwrap();
        let got_bits: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, reference_bits(&plan, &sample(8, i), &mut arena));
    }
    assert!(batcher.degraded(), "degraded mode is sticky for the batcher's lifetime");
}

#[test]
fn chaos_invariant_every_admitted_ticket_resolves_exactly_once() {
    let _g = ChaosGuard::arm(ChaosPlan {
        seed: 0xC0FFEE,
        panic_in_256: 32,
        delay_in_256: 32,
        nan_in_256: 32,
        delay_ms: 1,
    });
    let pool = Arc::new(Pool::new(2, 16));
    let fleet = Arc::new(Fleet::new(
        Arc::clone(&pool),
        FleetPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue_pending: 64,
            max_fleet_pending: 256,
            default_deadline: Some(Duration::from_millis(40)),
            degrade_after: 2,
            fault_budget: usize::MAX, // admission stays open for the storm
        },
    ));
    fleet.deploy("a", &zoo::tiny_mlp(1)).unwrap();
    fleet.deploy("b", &zoo::tiny_mlp(2)).unwrap();

    // Concurrent submitters over both models and both formats, against a
    // mixed panic/delay/NaN storm.
    let mut handles = Vec::new();
    for t in 0..4usize {
        let f = Arc::clone(&fleet);
        handles.push(std::thread::spawn(move || {
            let mut tickets = Vec::new();
            for i in 0..24usize {
                let model = if (t + i) % 2 == 0 { "a" } else { "b" };
                let format = if i % 2 == 0 {
                    ServeFormat::F64
                } else {
                    ServeFormat::Emulated { k: 12 }
                };
                if let Ok(ticket) = f.submit_blocking(model, format, sample(8, t * 100 + i)) {
                    tickets.push(ticket);
                }
            }
            tickets
        }));
    }
    // Racing hot swaps under the storm: in-flight tickets must drain on
    // the plans they were admitted under.
    for v in 0..8u64 {
        let id = if v % 2 == 0 { "a" } else { "b" };
        fleet.deploy(id, &zoo::tiny_mlp(1 + v)).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut tickets: Vec<Ticket> = Vec::new();
    for h in handles {
        tickets.extend(h.join().unwrap());
    }
    assert!(tickets.len() >= 90, "submitters were mostly admitted: {}", tickets.len());

    // Shutdown races the storm; when it returns, every admitted ticket
    // must already hold a typed outcome.
    fleet.shutdown();
    for (i, t) in tickets.iter().enumerate() {
        match t.try_take_typed() {
            Some(Ok(row)) => assert_eq!(row.len(), 3),
            Some(Err(e)) => match e {
                ServeError::DrivePanicked { .. }
                | ServeError::DeadlineExceeded { .. }
                | ServeError::NonFiniteOutput { .. }
                | ServeError::ExecFailed { .. } => {}
            },
            None => panic!("ticket {i} was admitted but never resolved"),
        }
        // Exactly once: the outcome was taken above, a second take must
        // find the slot empty.
        assert!(t.try_take_typed().is_none(), "ticket {i} resolved more than once");
    }
}

#[test]
fn same_seed_replays_the_same_fault_sequence() {
    let plan = ChaosPlan {
        seed: 0x5EED5,
        panic_in_256: 40,
        delay_in_256: 40,
        nan_in_256: 40,
        delay_ms: 3,
    };
    let _g = ChaosGuard::arm(plan);
    let draw = || -> Vec<Option<FaultKind>> {
        (0..64)
            .flat_map(|_| SITES.iter().map(|&s| faultinject::at(s)))
            .collect()
    };
    let first = draw();
    faultinject::arm(plan); // re-arming the same plan resets the sequence
    let second = draw();
    assert_eq!(first, second, "chaos must replay from the seed alone");
    assert!(first.iter().any(|d| d.is_some()), "a 120/256 mix must inject");
    assert!(first.iter().any(|d| d.is_none()), "and must also pass clean draws");
    assert!(
        first.contains(&Some(FaultKind::Delay { ms: 3 })),
        "delay draws carry the plan's stall length"
    );

    faultinject::disarm();
    for site in SITES {
        assert_eq!(faultinject::at(site), None, "disarmed sites draw nothing");
        assert!(!site.name().is_empty());
    }
}

#[test]
fn dropped_tickets_under_chaos_do_not_wedge_fleet_shutdown() {
    let _g = ChaosGuard::arm(ChaosPlan {
        seed: 0xDD,
        panic_in_256: 64,
        delay_in_256: 64,
        delay_ms: 2,
        ..ChaosPlan::default()
    });
    let pool = Arc::new(Pool::new(2, 8));
    let fleet = Fleet::new(
        Arc::clone(&pool),
        FleetPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            fault_budget: usize::MAX,
            ..FleetPolicy::default()
        },
    );
    fleet.deploy("m", &zoo::tiny_mlp(5)).unwrap();
    for i in 0..16 {
        // Drop every ticket immediately: the scatters become counted
        // no-ops and the drain below must still terminate.
        drop(fleet.submit_blocking("m", ServeFormat::F64, sample(8, i)).unwrap());
    }
    fleet.shutdown(); // must not hang on abandoned slots
    assert_eq!(fleet.snapshot().total_pending, 0);
}
