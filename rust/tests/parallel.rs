//! Parallel-execution integration tests: a pooled plan drive
//! ([`Plan::execute_batch_pooled`]) must be **bit-identical** to the
//! serial batched drive for every model in the zoo, for `f64` and
//! `EmulatedFp`, at every batch size and worker count — including under
//! a racing fleet saturating the same coordinator pool, and with the
//! hazard graph (`Plan::step_deps`) that licenses inter-op overlap.

use rigor::coordinator::Pool;
use rigor::fleet::{Fleet, FleetPolicy};
use rigor::model::{zoo, Model};
use rigor::plan::{Arena, Fusion, KernelPath, Parallelism, Plan, ServeFormat};
use rigor::quant::EmulatedFp;
use rigor::tensor::EmuCtx;
use rigor::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::avgpool_cnn(7),
        zoo::tiny_pendulum(3),
        zoo::scaled_mlp(4, 13, 17, 5),
        zoo::residual_mlp(5),
        zoo::residual_cnn(6),
    ]
}

fn batch_input(model: &Model, batch: usize, seed: u64) -> Vec<f64> {
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..batch * n).map(|_| rng.range(-1.0, 1.0)).collect()
}

fn assert_bits_eq(serial: &[f64], pooled: &[f64], what: &str) {
    assert_eq!(serial.len(), pooled.len(), "{what}: length");
    for (i, (a, b)) in serial.iter().zip(pooled).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: element {i} ({a} vs {b})");
    }
}

/// `min_work: 0` forces sharding even on the zoo's small steps — the
/// bit-identity contract must hold wherever the threshold lands.
fn eager(workers: usize) -> Parallelism {
    Parallelism { workers, min_work: 0 }
}

#[test]
fn pooled_drives_bit_identical_across_zoo_f64() {
    let pool = Pool::new(4, 16);
    for model in zoo_models() {
        for fusion in [Fusion::Full, Fusion::Pair] {
            let plan = Plan::build_with_kernels(&model, fusion, KernelPath::Blocked).unwrap();
            for batch in [1usize, 7, 32] {
                let flat = batch_input(&model, batch, 0x70 + batch as u64);
                let mut sa: Arena<f64> = Arena::new();
                let serial = plan
                    .execute_batch_path::<f64>(&(), &flat, batch, &mut sa, KernelPath::Blocked)
                    .unwrap()
                    .to_vec();
                for workers in [1usize, 2, 4] {
                    let mut pa: Arena<f64> = Arena::new();
                    let pooled = plan
                        .execute_batch_pooled::<f64>(
                            &(),
                            &flat,
                            batch,
                            &mut pa,
                            KernelPath::Blocked,
                            &pool,
                            eager(workers),
                        )
                        .unwrap()
                        .to_vec();
                    assert_bits_eq(
                        &serial,
                        &pooled,
                        &format!("{} {fusion:?} B={batch} W={workers}", model.name),
                    );
                }
            }
        }
    }
}

#[test]
fn pooled_drives_bit_identical_across_zoo_emulated() {
    let pool = Pool::new(4, 16);
    for model in zoo_models() {
        let plan = Plan::build_with_kernels(&model, Fusion::None, KernelPath::Blocked).unwrap();
        let ec = EmuCtx { k: 12 };
        for batch in [1usize, 7, 32] {
            let xe: Vec<EmulatedFp> = batch_input(&model, batch, 0xE7 + batch as u64)
                .iter()
                .map(|&v| EmulatedFp::new(v, 12))
                .collect();
            let mut sa: Arena<EmulatedFp> = Arena::new();
            let serial: Vec<f64> = plan
                .execute_batch_path::<EmulatedFp>(&ec, &xe, batch, &mut sa, KernelPath::Blocked)
                .unwrap()
                .iter()
                .map(|e| e.v)
                .collect();
            for workers in [1usize, 2, 4] {
                let mut pa: Arena<EmulatedFp> = Arena::new();
                let pooled: Vec<f64> = plan
                    .execute_batch_pooled::<EmulatedFp>(
                        &ec,
                        &xe,
                        batch,
                        &mut pa,
                        KernelPath::Blocked,
                        &pool,
                        eager(workers),
                    )
                    .unwrap()
                    .iter()
                    .map(|e| e.v)
                    .collect();
                assert_bits_eq(
                    &serial,
                    &pooled,
                    &format!("{} k=12 B={batch} W={workers}", model.name),
                );
            }
        }
    }
}

#[test]
fn pooled_drives_bit_identical_on_the_scalar_kernels() {
    // Sharding rides the blocked tables; a scalar-path pooled drive must
    // degrade to the serial scalar drive (still pooled for inter-op
    // waves), bit-identical.
    let pool = Pool::new(2, 8);
    for model in [zoo::residual_cnn(6), zoo::scaled_mlp(4, 13, 17, 5)] {
        let plan = Plan::build_with_kernels(&model, Fusion::Pair, KernelPath::Blocked).unwrap();
        let flat = batch_input(&model, 7, 0x5C);
        let mut sa: Arena<f64> = Arena::new();
        let serial = plan
            .execute_batch_path::<f64>(&(), &flat, 7, &mut sa, KernelPath::Scalar)
            .unwrap()
            .to_vec();
        let mut pa: Arena<f64> = Arena::new();
        let pooled = plan
            .execute_batch_pooled::<f64>(
                &(),
                &flat,
                7,
                &mut pa,
                KernelPath::Scalar,
                &pool,
                eager(4),
            )
            .unwrap()
            .to_vec();
        assert_bits_eq(&serial, &pooled, &format!("{} scalar pooled", model.name));
    }
}

#[test]
fn hazard_graph_orders_residual_branches() {
    // The dependency metadata that licenses inter-op overlap: every
    // step's predecessors must cover its read/write hazards. Spot-check
    // the residual models — a branchy graph has at least one step pair
    // with no path between them (the concurrent wave), while a pure
    // chain is totally ordered.
    for model in [zoo::residual_mlp(5), zoo::residual_cnn(6)] {
        let plan = Plan::build_with_kernels(&model, Fusion::Pair, KernelPath::Blocked).unwrap();
        let deps = plan.step_deps();
        let steps = plan.steps();
        assert_eq!(deps.len(), steps.len());
        // Transitive closure of "p precedes i".
        let n = deps.len();
        let mut reach = vec![vec![false; n]; n];
        for i in 0..n {
            for &p in &deps[i] {
                assert!(p < i, "{}: dep edges must point backwards", model.name);
                reach[i][p] = true;
                for j in 0..n {
                    if reach[p][j] {
                        reach[i][j] = true;
                    }
                }
            }
        }
        // Soundness: adjacent writers of the same buffer are ordered.
        for i in 0..n {
            for j in 0..i {
                let rw_hazard = steps[i].inputs.contains(&steps[j].out)
                    || steps[j].inputs.contains(&steps[i].out)
                    || steps[i].out == steps[j].out;
                if rw_hazard {
                    assert!(
                        reach[i][j],
                        "{}: steps {j} -> {i} share a buffer but are unordered",
                        model.name
                    );
                }
            }
        }
        // Branchiness: some pair is unordered in both directions.
        let mut concurrent = false;
        for i in 0..n {
            for j in 0..i {
                if !reach[i][j] && !reach[j][i] {
                    concurrent = true;
                }
            }
        }
        assert!(concurrent, "{}: residual graph has no concurrent steps", model.name);
    }
    // A pure chain is totally ordered: no concurrent pair.
    let plan =
        Plan::build_with_kernels(&zoo::tiny_mlp(1), Fusion::Pair, KernelPath::Blocked).unwrap();
    let deps = plan.step_deps();
    for (i, d) in deps.iter().enumerate().skip(1) {
        assert!(d.contains(&(i - 1)), "chain step {i} must depend on its predecessor");
    }
}

#[test]
fn pooled_drives_stay_deterministic_under_a_racing_fleet() {
    // The production configuration: the same coordinator pool serves
    // fleet traffic while an analysis-side pooled drive shards onto it.
    // Every drive must reproduce the serial bits no matter how the
    // scheduler interleaves jobs.
    let pool = Arc::new(Pool::new(4, 32));
    let model = zoo::residual_cnn(6);
    let plan = Plan::build_with_kernels(&model, Fusion::Pair, KernelPath::Blocked).unwrap();
    let batch = 13usize;
    let flat = batch_input(&model, batch, 0xFEE7);
    let mut sa: Arena<f64> = Arena::new();
    let serial = plan
        .execute_batch_path::<f64>(&(), &flat, batch, &mut sa, KernelPath::Blocked)
        .unwrap()
        .to_vec();

    let fleet = Arc::new(Fleet::new(
        Arc::clone(&pool),
        FleetPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_queue_pending: 256,
            max_fleet_pending: 1024,
            ..FleetPolicy::default()
        },
    ));
    fleet.deploy("noise", &zoo::tiny_cnn(2)).unwrap();
    let cnn_n: usize = zoo::tiny_cnn(2).input_shape.iter().product();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let traffic = {
        let stop = Arc::clone(&stop);
        let f = Arc::clone(&fleet);
        std::thread::spawn(move || {
            let mut i = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let s: Vec<f64> =
                    (0..cnn_n).map(|j| ((i + j) % 17) as f64 / 17.0).collect();
                if let Ok(t) = f.submit_blocking("noise", ServeFormat::F64, s) {
                    let _ = t.wait();
                }
                i += 1;
            }
        })
    };

    let mut pa: Arena<f64> = Arena::new();
    for round in 0..24 {
        let workers = 1 + round % 4;
        let pooled = plan
            .execute_batch_pooled::<f64>(
                &(),
                &flat,
                batch,
                &mut pa,
                KernelPath::Blocked,
                &pool,
                eager(workers),
            )
            .unwrap()
            .to_vec();
        assert_bits_eq(&serial, &pooled, &format!("round {round} W={workers}"));
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    traffic.join().unwrap();
    fleet.shutdown(); // drain every admitted ticket before the pool drops
}

#[test]
fn pooled_executor_allocations_stay_bounded_per_drive() {
    // The pooled executor may allocate small constant scheduler state
    // (wave lists, scope nodes, job boxes) but must not scale with drive
    // count — the arena and per-worker scratch absorb the data-plane
    // buffers after warmup.
    let pool = Pool::new(2, 8);
    let model = zoo::residual_cnn(6);
    let plan = Plan::build_with_kernels(&model, Fusion::Pair, KernelPath::Blocked).unwrap();
    let batch = 8usize;
    let flat = batch_input(&model, batch, 0xA110C);
    let mut arena: Arena<f64> = Arena::new();
    for _ in 0..3 {
        plan.execute_batch_pooled::<f64>(
            &(),
            &flat,
            batch,
            &mut arena,
            KernelPath::Blocked,
            &pool,
            eager(2),
        )
        .unwrap();
    }
    // Warm: measure 8 more drives. The budget is generous (scheduler
    // state per step per drive) but catches per-element regressions,
    // which would show up as thousands of allocations.
    let drives = 8u64;
    let before = thread_allocs();
    for _ in 0..drives {
        plan.execute_batch_pooled::<f64>(
            &(),
            &flat,
            batch,
            &mut arena,
            KernelPath::Blocked,
            &pool,
            eager(2),
        )
        .unwrap();
    }
    let allocs = thread_allocs() - before;
    let budget = drives * 64 * plan.steps().len() as u64;
    assert!(allocs <= budget, "pooled drives allocated {allocs} (> {budget})");

    // And the serial fallback through the same entry point stays
    // strictly allocation-free once warm.
    plan.execute_batch_pooled::<f64>(
        &(),
        &flat,
        batch,
        &mut arena,
        KernelPath::Blocked,
        &pool,
        Parallelism::serial(),
    )
    .unwrap();
    let before = thread_allocs();
    plan.execute_batch_pooled::<f64>(
        &(),
        &flat,
        batch,
        &mut arena,
        KernelPath::Blocked,
        &pool,
        Parallelism::serial(),
    )
    .unwrap();
    assert_eq!(thread_allocs() - before, 0, "serial fallback must stay allocation-free");
}

// ---- allocation counter (same per-thread hook as tests/kernels.rs) --------

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counter hook has no
// effect on allocation behavior.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;
