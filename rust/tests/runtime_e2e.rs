//! End-to-end tests over the AOT artifacts: PJRT execution, agreement
//! between the Rust engine and the lowered JAX/Pallas computation, and the
//! Rust/Pallas roundk cross-check. All tests skip (with a notice) until
//! `make artifacts` has produced `artifacts/manifest.json`.

use rigor::data::Dataset;
use rigor::model::Model;
use rigor::quant::round_to_precision;
use rigor::runtime::Runtime;
use rigor::tensor::Tensor;
use rigor::util::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_available() {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        return None;
    }
    Some(Runtime::open(&Runtime::default_dir()).expect("open artifacts"))
}

fn load_model(name: &str) -> Model {
    Model::load(&Runtime::default_dir().join("models").join(format!("{name}.json")))
        .expect("load model json")
}

fn load_data(name: &str) -> Dataset {
    Dataset::load(
        &Runtime::default_dir()
            .join("data")
            .join(format!("{name}_eval.json")),
    )
    .expect("load dataset")
}

#[test]
fn manifest_covers_all_models_and_variants() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.manifest.model_names();
    for m in ["digits", "mobilenet_mini", "pendulum", "roundk"] {
        assert!(names.iter().any(|n| n == m), "missing artifact family {m}");
    }
    for m in ["digits", "mobilenet_mini", "pendulum"] {
        assert!(rt.manifest.find(m, "f32").is_some());
        assert!(!rt.precision_variants(m).is_empty());
    }
}

#[test]
fn pjrt_runs_and_matches_rust_engine_f64() {
    // The same trained weights evaluated by (a) the PJRT-compiled
    // JAX/Pallas graph in f32 and (b) the Rust engine in f64 must agree to
    // f32 tolerance — proving the JSON export, the engine semantics and
    // the AOT path all describe the same network.
    let Some(mut rt) = runtime_or_skip() else { return };
    for name in ["digits", "mobilenet_mini", "pendulum"] {
        let model = load_model(name);
        let data = load_data(name);
        for (si, sample) in data.inputs.iter().take(5).enumerate() {
            let input_f32: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
            let got = rt.run(name, "f32", &input_f32).expect("pjrt run");
            let want = model
                .forward::<f64>(&(), Tensor::new(model.input_shape.clone(), sample.clone()))
                .expect("rust engine run");
            assert_eq!(got.len(), want.len(), "{name} output size");
            for (i, (g, w)) in got.iter().zip(want.data()).enumerate() {
                let tol = 1e-3 * (1.0 + w.abs());
                assert!(
                    ((*g as f64) - w).abs() < tol,
                    "{name} sample {si} output {i}: pjrt={g} rust={w}"
                );
            }
        }
    }
}

#[test]
fn roundk_kernel_matches_rust_quant() {
    // The Pallas roundk kernel (through PJRT) and quant::round_to_precision
    // are twins: bit-identical on f32 inputs.
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(1234);
    for k in rt.precision_variants("roundk") {
        let input: Vec<f32> = (0..64)
            .map(|i| match i % 4 {
                0 => rng.range(-1.0, 1.0) as f32,
                1 => rng.range(-1e4, 1e4) as f32,
                2 => rng.range(-1e-4, 1e-4) as f32,
                _ => rng.below(256) as f32,
            })
            .collect();
        let got = rt
            .run("roundk", &format!("k{k}"), &input)
            .expect("roundk run");
        for (i, (g, x)) in got.iter().zip(&input).enumerate() {
            // Round the f32 (exactly representable in f64) with the Rust
            // twin; results must agree bit-for-bit.
            let want = round_to_precision(*x as f64, k) as f32;
            assert!(
                g.to_bits() == want.to_bits(),
                "k={k} elem {i}: pallas {g:?} vs rust {want:?} (x={x:?})"
            );
        }
    }
}

#[test]
fn precision_variants_degrade_gracefully() {
    // Storage-emulated k variants stay close to f32 for large k and drift
    // monotonically-ish as k shrinks; argmax survives at k=8 on confident
    // samples (the paper's headline).
    let Some(mut rt) = runtime_or_skip() else { return };
    let data = load_data("digits");
    let sample: Vec<f32> = data.inputs[0].iter().map(|&v| v as f32).collect();
    let ref_out = rt.run("digits", "f32", &sample).unwrap();
    let ref_top = argmax(&ref_out);
    let mut prev_dev = f64::INFINITY;
    for k in [8u32, 12, 16, 20] {
        let out = rt.run("digits", &format!("k{k}"), &sample).unwrap();
        let dev = out
            .iter()
            .zip(&ref_out)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        assert!(
            dev <= prev_dev * 4.0 + 1e-6,
            "k={k} deviation {dev} vs previous {prev_dev}"
        );
        prev_dev = dev;
        if ref_out[ref_top] > 0.6 {
            assert_eq!(argmax(&out), ref_top, "k={k} flipped a confident argmax");
        }
    }
}

#[test]
fn whole_eval_set_classified_consistently_at_k8() {
    // E-acc-vs-k headline at k=8 over the full exported eval set.
    let Some(mut rt) = runtime_or_skip() else { return };
    let data = load_data("digits");
    let mut flips = 0;
    let mut total = 0;
    for sample in &data.inputs {
        let s: Vec<f32> = sample.iter().map(|&v| v as f32).collect();
        let r = rt.run("digits", "f32", &s).unwrap();
        let e = rt.run("digits", "k8", &s).unwrap();
        total += 1;
        if argmax(&r) != argmax(&e) {
            flips += 1;
        }
    }
    assert!(total >= 20);
    assert!(
        flips * 10 <= total,
        "k=8 flipped {flips}/{total} — far above the paper's observation"
    );
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
