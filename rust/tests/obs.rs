//! Observability-layer integration tests: the `ObsPolicy::Disabled`
//! hot path must add **zero** steady-state allocations, `Full` tracing
//! must be allocation-free after warmup (ring, tag table, epoch and
//! thread ids are populated once), and turning tracing on or off must
//! never change a single output bit — for plain `f64` serving and for
//! CAA analysis (where tracing swaps in the bound-probe step walk) —
//! across the whole model zoo. Plus the span-nesting contract on a
//! served round trip: request ⊇ flush ⊇ drive ⊇ wave ⊇ step.

use rigor::analysis::{analyze_class, bound_profile_with_plan, AnalysisConfig};
use rigor::caa::Ctx;
use rigor::coordinator::Pool;
use rigor::model::{zoo, Model};
use rigor::obs::{self, ObsPolicy, SpanKind, TraceSink};
use rigor::plan::{Arena, Fusion, KernelPath, Parallelism, Plan, ServeFormat};
use rigor::serve::{BatchPolicy, MicroBatcher};
use rigor::util::Rng;

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

// ---- allocation counter ---------------------------------------------------
// Same counting wrapper as tests/kernels.rs: per-thread counter so
// concurrently running tests don't pollute each other's measurements.

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counter hook has no
// effect on allocation behavior.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// ---- policy lock ----------------------------------------------------------
// The obs policy is process-global, so every test that flips it holds
// this lock for its whole body. `set_policy` (not the RIGOR_TRACE env)
// decides the level, so these tests behave the same under the CI run
// that exports RIGOR_TRACE=full.

fn policy_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

// ---- helpers --------------------------------------------------------------

fn zoo_models() -> Vec<Model> {
    vec![
        zoo::tiny_mlp(1),
        zoo::tiny_cnn(2),
        zoo::avgpool_cnn(7),
        zoo::tiny_pendulum(3),
        zoo::scaled_mlp(4, 13, 17, 5),
        zoo::residual_mlp(5),
        zoo::residual_cnn(6),
    ]
}

fn batch_input(model: &Model, batch: usize, seed: u64) -> Vec<f64> {
    let n: usize = model.input_shape.iter().product();
    let mut rng = Rng::new(seed);
    (0..batch * n).map(|_| rng.range(-1.0, 1.0)).collect()
}

fn assert_bits_eq(off: &[f64], on: &[f64], what: &str) {
    assert_eq!(off.len(), on.len(), "{what}: length");
    for (i, (a, b)) in off.iter().zip(on).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: element {i} ({a} vs {b})");
    }
}

fn caa_cfg() -> AnalysisConfig {
    AnalysisConfig {
        ctx: Ctx::with_u_max(2f64.powi(-21)),
        p_star: 0.6,
        input_radius: 0.0,
        exact_inputs: false,
    }
}

// ---- zero-overhead contract -----------------------------------------------

/// `ObsPolicy::Disabled` on the serve hot path (the instrumented
/// `execute_batch_path` drive loop): after the arena warms up, repeated
/// drives must allocate **nothing** — the mark/record sites compile down
/// to one relaxed load and a branch.
#[test]
fn disabled_drive_hot_path_is_allocation_free() {
    let _g = policy_guard();
    obs::set_policy(ObsPolicy::Disabled);

    let model = zoo::tiny_cnn(2);
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let big = batch_input(&model, 32, 0x0B5);
    let small = batch_input(&model, 7, 0x0B6);
    let mut arena: Arena<f64> = Arena::new();

    // Warm: monotonic arena reservations for both batch shapes.
    plan.execute_batch_path::<f64>(&(), &big, 32, &mut arena, KernelPath::Blocked).unwrap();
    plan.execute_batch_path::<f64>(&(), &small, 7, &mut arena, KernelPath::Blocked).unwrap();

    let before = thread_allocs();
    for _ in 0..5 {
        plan.execute_batch_path::<f64>(&(), &big, 32, &mut arena, KernelPath::Blocked).unwrap();
        plan.execute_batch_path::<f64>(&(), &small, 7, &mut arena, KernelPath::Blocked).unwrap();
    }
    let extra = thread_allocs() - before;
    assert_eq!(extra, 0, "disabled obs policy must not allocate on the drive hot path");
}

/// Even `Full` tracing is allocation-free at steady state: the span ring
/// is fixed-capacity atomics, histograms are fixed atomic buckets, and
/// the tag intern table stops growing once every site tag has been seen.
/// Only the first traced drive (ring + epoch + tag + thread-id setup)
/// may allocate.
#[test]
fn full_tracing_steady_state_is_allocation_free_after_warmup() {
    let _g = policy_guard();
    obs::set_policy(ObsPolicy::Full);

    let model = zoo::tiny_cnn(3);
    let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
    let big = batch_input(&model, 32, 0x0F5);
    let small = batch_input(&model, 7, 0x0F6);
    let mut arena: Arena<f64> = Arena::new();

    // Warm: arena reservations + obs one-time state (ring allocation,
    // trace epoch, this thread's dense id, every step tag interned).
    for _ in 0..2 {
        plan.execute_batch_path::<f64>(&(), &big, 32, &mut arena, KernelPath::Blocked).unwrap();
        plan.execute_batch_path::<f64>(&(), &small, 7, &mut arena, KernelPath::Blocked).unwrap();
    }

    let before = thread_allocs();
    for _ in 0..5 {
        plan.execute_batch_path::<f64>(&(), &big, 32, &mut arena, KernelPath::Blocked).unwrap();
        plan.execute_batch_path::<f64>(&(), &small, 7, &mut arena, KernelPath::Blocked).unwrap();
    }
    let extra = thread_allocs() - before;
    obs::set_policy(ObsPolicy::Disabled);
    assert_eq!(extra, 0, "full tracing must not allocate once warm (ring/tags/epoch exist)");
}

// ---- bitwise identity -----------------------------------------------------

/// Tracing on vs off never changes an `f64` output bit, zoo-wide, at
/// single-sample and batched entry points.
#[test]
fn tracing_never_changes_f64_outputs_zoo_wide() {
    let _g = policy_guard();
    for model in zoo_models() {
        let plan = Plan::build_with_kernels(&model, Fusion::Full, KernelPath::Blocked).unwrap();
        for batch in [1usize, 5] {
            let flat = batch_input(&model, batch, 0xB17 + batch as u64);

            obs::set_policy(ObsPolicy::Disabled);
            let mut a0: Arena<f64> = Arena::new();
            let off = plan
                .execute_batch_path::<f64>(&(), &flat, batch, &mut a0, KernelPath::Blocked)
                .unwrap()
                .to_vec();

            obs::set_policy(ObsPolicy::Full);
            let mut a1: Arena<f64> = Arena::new();
            let on = plan
                .execute_batch_path::<f64>(&(), &flat, batch, &mut a1, KernelPath::Blocked)
                .unwrap()
                .to_vec();

            assert_bits_eq(&off, &on, &format!("{} B={batch}", model.name));
        }
    }
    obs::set_policy(ObsPolicy::Disabled);
}

/// Tracing on vs off never changes a CAA analysis result, zoo-wide.
/// Under `Full` the analysis runs the bound-probe walk (`load_input` +
/// per-step `execute_step`) instead of `Plan::execute`; both must land
/// on bitwise-identical output bounds, and the probe must leave a
/// per-step profile in the registry.
#[test]
fn tracing_never_changes_caa_analysis_zoo_wide() {
    let _g = policy_guard();
    let cfg = caa_cfg();
    for model in zoo_models() {
        let sample = batch_input(&model, 1, 0xCAA);

        obs::set_policy(ObsPolicy::Disabled);
        let off = analyze_class(&model, &cfg, 0, &sample).unwrap();

        obs::set_policy(ObsPolicy::Full);
        obs::registry().reset();
        let on = analyze_class(&model, &cfg, 0, &sample).unwrap();

        assert_eq!(
            off.max_abs_u.to_bits(),
            on.max_abs_u.to_bits(),
            "{}: max_abs_u ({} vs {})",
            model.name,
            off.max_abs_u,
            on.max_abs_u
        );
        assert_eq!(
            off.max_rel_u.to_bits(),
            on.max_rel_u.to_bits(),
            "{}: max_rel_u ({} vs {})",
            model.name,
            off.max_rel_u,
            on.max_rel_u
        );
        assert_eq!(off.predicted, on.predicted, "{}: predicted class", model.name);
        assert_eq!(off.ambiguous, on.ambiguous, "{}: ambiguity flag", model.name);

        let profile = obs::registry().bounds().expect("traced analysis records a bound profile");
        assert_eq!(profile.model, model.name, "profile tagged with the analyzed model");
        assert!(!profile.steps.is_empty(), "{}: probe recorded steps", model.name);
        for st in &profile.steps {
            assert!(st.out_len > 0, "{} step {}: empty output", model.name, st.index);
            assert!(st.abs_u >= 0.0, "{} step {}: abs width", model.name, st.index);
        }
    }
    obs::set_policy(ObsPolicy::Disabled);
}

// ---- bound profile --------------------------------------------------------

/// `bound_profile_with_plan` over an unfused plan yields one row per
/// plan step, in order, with the conv step visibly widening the
/// relative bound from the (near-exact) inputs.
#[test]
fn bound_profile_tracks_every_unfused_step() {
    let _g = policy_guard();
    obs::set_policy(ObsPolicy::Disabled); // the probe API is policy-independent
    let model = zoo::tiny_cnn(4);
    let plan = Plan::unfused(&model).unwrap();
    let sample = batch_input(&model, 1, 0x9F);
    let profile = bound_profile_with_plan(&plan, &caa_cfg(), &sample).unwrap();

    assert_eq!(profile.model, model.name);
    assert_eq!(profile.steps.len(), plan.steps().len(), "one profile row per plan step");
    for (i, (st, step)) in profile.steps.iter().zip(plan.steps()).enumerate() {
        assert_eq!(st.index, i, "rows in step order");
        assert_eq!(st.kind, step.kind.name(), "row {i} tagged with its step kind");
        assert!(st.out_len > 0, "row {i}: output length");
        assert!(st.abs_u >= 0.0 && !st.abs_u.is_nan(), "row {i}: abs width");
        assert!(st.secs >= 0.0, "row {i}: wall clock");
    }
    let conv = profile
        .steps
        .iter()
        .find(|s| s.kind == "conv2d")
        .expect("tiny_cnn profile has a conv2d row");
    assert!(
        conv.rel_u > 0.0,
        "conv widens the relative bound away from the exact inputs (got {})",
        conv.rel_u
    );
}

// ---- span nesting on a served round trip ----------------------------------

/// A pooled serve round trip under `Full` tracing records the whole
/// span hierarchy — request, flush, drive, wave, step — with every
/// ticket's trace id minted non-zero and child spans contained in a
/// parent window (to microsecond truncation).
#[test]
fn serve_round_trip_records_nested_spans() {
    let _g = policy_guard();
    obs::set_policy(ObsPolicy::Full);
    TraceSink::clear();
    obs::registry().reset();

    let model = zoo::residual_cnn(6);
    let n: usize = model.input_shape.iter().product();
    let plan = Arc::new(Plan::for_format(&model, ServeFormat::F64).unwrap());
    let kernels = plan.kernel_path();
    let steps = plan.steps().len();
    let mut batcher = MicroBatcher::with_parallelism(
        plan,
        Arc::new(Pool::new(4, 32)),
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            max_pending: 64,
            ..BatchPolicy::default()
        },
        kernels,
        ServeFormat::F64,
        Parallelism::with_workers(4),
    );

    const REQS: usize = 16;
    let tickets: Vec<_> =
        (0..REQS).map(|i| batcher.submit(batch_input(&model, 1, 0x600 + i as u64)).unwrap()).collect();
    let mut traces = Vec::new();
    for t in tickets {
        assert_ne!(t.trace_id(), 0, "full tracing mints a non-zero trace id per ticket");
        traces.push(t.trace_id());
        t.wait().unwrap();
    }
    batcher.shutdown();

    let spans = TraceSink::spans();
    let of = |k: SpanKind| spans.iter().filter(|s| s.kind == k).collect::<Vec<_>>();
    let (requests, flushes, drives, waves, step_spans) = (
        of(SpanKind::Request),
        of(SpanKind::Flush),
        of(SpanKind::Drive),
        of(SpanKind::Wave),
        of(SpanKind::Step),
    );

    assert!(requests.len() >= REQS, "one request span per resolved ticket ({})", requests.len());
    assert!(!flushes.is_empty(), "at least one flush span");
    assert!(!drives.is_empty(), "at least one drive span");
    assert!(!waves.is_empty(), "pooled drives record wave spans");
    assert!(step_spans.len() >= steps, "at least one span per plan step ({})", step_spans.len());

    let request_traces: Vec<u64> = requests.iter().map(|s| s.trace).collect();
    for t in &traces {
        assert!(request_traces.contains(t), "ticket trace {t} has a request span");
    }
    for f in &flushes {
        assert_ne!(f.trace, 0, "flush spans carry a representative trace id");
        assert!(traces.contains(&f.trace), "flush trace {} belongs to a ticket", f.trace);
    }

    // Containment to microsecond-truncation slack: child start never
    // precedes the parent's (both truncate down from a later clock
    // read), child end may overrun by the two truncations.
    let within = |c: &rigor::obs::Span, p: &rigor::obs::Span, slack: u64| {
        p.start_us <= c.start_us && c.start_us + c.dur_us <= p.start_us + p.dur_us + slack
    };
    for d in &drives {
        assert!(
            flushes.iter().any(|f| within(d, f, 2)),
            "drive span at {}+{} inside a flush",
            d.start_us,
            d.dur_us
        );
    }
    for w in &waves {
        assert!(
            drives.iter().any(|d| within(w, d, 2)),
            "wave span at {}+{} inside a drive",
            w.start_us,
            w.dur_us
        );
    }
    for s in &step_spans {
        assert!(
            drives.iter().any(|d| within(s, d, 3)),
            "step span '{}' at {}+{} inside a drive",
            s.tag,
            s.start_us,
            s.dur_us
        );
    }

    // The latency histograms saw every request.
    let lat = obs::registry().submit_to_resolve.stats();
    assert!(lat.count >= REQS as u64, "submit→resolve histogram recorded {} samples", lat.count);
    assert!(obs::registry().queue_wait.stats().count >= REQS as u64, "queue-wait per sample");
    assert!(obs::registry().step_exec.stats().count > 0, "step-execute histogram populated");

    let snap = obs::Snapshot::capture();
    assert!(snap.spans_recorded > 0, "snapshot sees the recorded spans");
    obs::set_policy(ObsPolicy::Disabled);
}
